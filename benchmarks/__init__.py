"""Benchmarks: one module per paper table/figure + the roofline harness."""
