"""Paper Figs. 7 + 16: nonlinear data augmentation in some workers.

f=3 workers train on Lotka-Volterra / Arnold-Cat-Map-augmented data with
Gaussian noise — the dependent-noise regime the paper argues breaks
distance-threshold aggregators.
"""

from __future__ import annotations

from benchmarks.common import ByzRunConfig, emit, run_byzantine_training


def run(steps: int = 100):
    rows = [("name", "us_per_call", "derived")]
    for scheme in (("lotka_volterra",) if steps <= 20 else ("lotka_volterra", "cat_map", "smooth_cat_map")):
        for agg in (("flag", "mean") if steps <= 20 else ("flag", "multi_krum", "bulyan", "mean")):
            cfg = ByzRunConfig(
                f=0, aggregator=agg, steps=steps, attack="none",
                augment_scheme=scheme, augment_workers=3,
                gaussian_sigma=0.10)
            out = run_byzantine_training(cfg)
            rows.append((f"augment/{scheme}/{agg}",
                         f"{out['us_per_step']:.0f}",
                         f"acc={out['final_accuracy']:.4f}"))
            print(rows[-1])
    emit(rows, "augmentation")
    return rows


if __name__ == "__main__":
    run()
