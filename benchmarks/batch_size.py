"""Paper Fig. 5: marginal utility of larger batch sizes at fixed f = 3.

The paper's claim: with larger per-worker batches FA reaches a
significantly better accuracy than the other robust aggregators.
"""

from __future__ import annotations

from benchmarks.common import ByzRunConfig, emit, run_byzantine_training


def run(steps: int = 100, batches=(16, 32, 64, 128),
        aggs=("flag", "multi_krum", "bulyan", "median")):
    rows = [("name", "us_per_call", "derived")]
    for b in batches:
        for agg in aggs:
            cfg = ByzRunConfig(f=3, batch=b, aggregator=agg, steps=steps,
                               attack="random", attack_kw={"scale": 5.0})
            out = run_byzantine_training(cfg)
            rows.append((f"batch_size/{agg}/B={b}",
                         f"{out['us_per_step']:.0f}",
                         f"acc={out['final_accuracy']:.4f}"))
            print(rows[-1])
    emit(rows, "batch_size")
    return rows


if __name__ == "__main__":
    run()
