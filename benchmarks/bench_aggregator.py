"""The BENCH trajectory: solver + Gram + combine wall-clock of the FA hot path.

Times the three stages of a distributed FA aggregation step across
p in {8, 16, 32, 64} workers x n in {1e5, 1e6} coordinates:

* **solver** — ``fa_weights_from_gram`` with ``solver='qspace'`` (the
  original q x q eigh, q = p + p(p-1)/2) vs ``solver='rank_p'`` (the p x p
  closed-Laplacian IRLS).  Solver cost is n-independent (it sees only the
  (p, p) Gram), so each (p, solver) pair is timed once and reused.
* **gram** — ``tree_gram`` looped (one kernel dispatch + 128-lane re-pad
  per leaf) vs fused (whole pytree packed into one chunk stream, a single
  kernel call).
* **combine** — ``tree_combine`` (n-dependent weighted reduction).

Results land in ``BENCH_aggregator.json`` at the repo root — the start of
the perf trajectory.  ``summary`` reports the q-space/rank-p speedup per p
and the crossover worker count; ``tiny`` holds the CI perf-smoke baseline
(see ``--tiny`` / ``--check-baseline`` below and the ``perf-smoke`` lane
in ``.github/workflows/ci.yml``).

The ``rules`` section (``--rules``, or part of the default full run)
times every aggregation rule end-to-end through ``aggregate_tree`` at
impl in {xla, pallas} — the trajectory that tracks the coordinate-rule
selection-network kernel (``kernels/coord_stats``, docs/coord_stats.md);
``rules_tiny`` is its CI-scale twin and the second perf-smoke sub-gate.

Wall-clock numbers are machine-dependent, so the CI gate normalizes by a
fixed-size numpy matmul calibration stored alongside the baseline: a run
fails only if the rank-p tiny wall-clock (or a pallas-impl coordinate
rule) regresses >2x after scaling by the calibration ratio (slow runner
!= regression; slow solver == regression).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flag import FlagConfig
from repro.core.gram import fa_weights_from_gram
from repro.dist.aggregation import tree_combine, tree_gram

REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_JSON = Path(os.environ.get("REPRO_BENCH_AGG_JSON",
                                 REPO_ROOT / "BENCH_aggregator.json"))


def time_call(fn, *args, iters: int = 5):
    """Mean wall-clock microseconds per call (one warm-up, then timed).

    The warm-up triggers compilation and is fully synchronized via
    ``jax.block_until_ready`` (works on any pytree result), so the timed
    loop measures steady-state execution only.
    """
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def calibration_us(iters: int = 3) -> float:
    """Machine-speed probe: fixed 512^3 fp32 numpy matmul, us per call.

    Stored with every emitted section so perf gates can compare wall-clock
    across machines of different speed (see ``check_baseline``).
    """
    a = np.random.default_rng(0).normal(size=(512, 512)).astype(np.float32)
    a @ a  # warm-up
    t0 = time.perf_counter()
    for _ in range(iters):
        a @ a
    return (time.perf_counter() - t0) / iters * 1e6


def write_bench_json(section: str, payload, path: Path = BENCH_JSON) -> None:
    """Merge ``payload`` under ``section`` in the shared BENCH json.

    Every benchmark that contributes to the perf trajectory routes its
    rows through here (``bench_aggregator`` itself, ``wallclock.py``, the
    CI tiny runs) so the trajectory accumulates in one file.
    """
    doc = {}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError:
            doc = {}
    doc[section] = payload
    path.write_text(json.dumps(doc, indent=1, default=float) + "\n")
    print(f"[bench_aggregator] wrote section {section!r} -> {path}")


def _worker_tree(rng, p: int, n: int, leaves: int = 6):
    """Worker-major pytree with `leaves` leaves totaling ~n coordinates."""
    sizes = [n // leaves] * (leaves - 1)
    sizes.append(n - sum(sizes))
    return {f"leaf{i}": jnp.asarray(rng.normal(size=(p, s)), jnp.float32)
            for i, s in enumerate(sizes)}


def bench_solver(p: int, iters: int, cfg: FlagConfig):
    """(us_qspace, us_rank_p) for the IRLS solve on a (p, p) Gram."""
    rng = np.random.default_rng(p)
    G = jnp.asarray(rng.normal(size=(4 * p, p)), jnp.float32)
    K = (G.T @ G).block_until_ready()
    out = {}
    for solver in ("qspace", "rank_p"):
        fn = jax.jit(lambda k, s=solver: fa_weights_from_gram(k, cfg,
                                                              solver=s)[0])
        out[solver] = time_call(fn, K, iters=iters)
    return out["qspace"], out["rank_p"]


def run(ps=(8, 16, 32, 64), ns=(100_000, 1_000_000), *, iters: int = 3,
        impl: str = "xla", section: str = "aggregator",
        path: Path = BENCH_JSON):
    records = []
    # n-dependent stages first: the q-space solver at large p allocates
    # O(p^4) scratch (a 2080^2 eigh workspace at p=64) and measurably
    # fragments the allocator — timing gram/combine before the solvers
    # keeps their numbers clean.
    stage_us = {}
    for p in ps:
        for n in ns:
            rng = np.random.default_rng(p * 1000 + 1)
            tree = jax.block_until_ready(_worker_tree(rng, p, n))
            gram_looped = jax.jit(
                lambda t: tree_gram(t, impl=impl, fused=False))
            gram_fused = jax.jit(lambda t: tree_gram(t, impl=impl))
            us_gram = {"looped": time_call(gram_looped, tree, iters=iters),
                       "fused": time_call(gram_fused, tree, iters=iters)}
            c = jnp.full((p,), 1.0 / p, jnp.float32)
            us_combine = time_call(
                jax.jit(lambda t, w: tree_combine(t, w)), tree, c,
                iters=iters)
            stage_us[p, n] = (us_gram, us_combine)
            print(f"p={p} n={n}: gram looped={us_gram['looped']:.0f}us "
                  f"fused={us_gram['fused']:.0f}us "
                  f"combine={us_combine:.0f}us")
    solver_us = {}
    for p in ps:
        cfg = FlagConfig(lam=float(p))
        solver_us[p] = bench_solver(p, iters, cfg)
        q = p + p * (p - 1) // 2
        print(f"solver p={p} (q={q}): qspace={solver_us[p][0]:.0f}us "
              f"rank_p={solver_us[p][1]:.0f}us "
              f"speedup={solver_us[p][0] / solver_us[p][1]:.1f}x")
    for p in ps:
        for n in ns:
            us_gram, us_combine = stage_us[p, n]
            for solver, us_solver in zip(("qspace", "rank_p"), solver_us[p]):
                for gram_mode, ug in us_gram.items():
                    records.append({
                        "p": p, "n": n, "solver": solver, "gram": gram_mode,
                        "us_solver": round(us_solver, 1),
                        "us_gram": round(ug, 1),
                        "us_combine": round(us_combine, 1),
                        "us_total": round(us_solver + ug + us_combine, 1),
                    })

    speedups = {p: solver_us[p][0] / solver_us[p][1] for p in ps}
    crossover = next((p for p in sorted(ps) if speedups[p] > 1.0), None)
    n_big = max(ns)
    # structural witness: the fused path is ONE pallas_call per pytree
    probe = _worker_tree(np.random.default_rng(0), min(ps), 1024, leaves=4)
    fused_calls = str(jax.make_jaxpr(
        lambda t: tree_gram(t, impl="pallas_interpret"))(probe)
    ).count("pallas_call")
    summary = {
        "solver_speedup_qspace_over_rank_p": {str(p): round(s, 2)
                                              for p, s in speedups.items()},
        "solver_crossover_p": crossover,
        "crossover_note": (
            f"rank-p wins from p={crossover} on this host; the gap is "
            "asymptotic — per IRLS iteration q-space pays O(q^3)=O(p^6) "
            "(eigh on q=p+p(p-1)/2) vs rank-p's O(p^3), so the speedup "
            "grows ~p^3"),
        "fused_pallas_calls_multi_leaf_tree": fused_calls,
        "gram_note": (
            "fused = one chunk plan for the whole pytree: a single "
            "pallas_call on TPU, the piecewise XLA schedule elsewhere; "
            "looped = one dispatch + 128-lane re-pad per leaf with "
            "materialized strided copies under sketch_stride"),
        "gram_fused_speedup_at_largest": {
            str(p): round(
                next(r for r in records if r["p"] == p and r["n"] == n_big
                     and r["gram"] == "looped")["us_gram"]
                / next(r for r in records if r["p"] == p and r["n"] == n_big
                       and r["gram"] == "fused")["us_gram"], 2)
            for p in ps},
    }
    payload = {"config": {"ps": list(ps), "ns": list(ns), "iters": iters,
                          "impl": impl, "backend": jax.default_backend()},
               "calibration_us": round(calibration_us(), 1),
               "records": records, "summary": summary}
    if path is not None:
        write_bench_json(section, payload, path)
    return payload


def run_tiny(path: Path | None = BENCH_JSON):
    """CI perf-smoke config: small p/n, interpret-friendly, seconds-scale.

    ``path=None`` measures without touching the shared json (the
    ``check_baseline`` probe).
    """
    return run(ps=(4, 8), ns=(4096,), iters=2, section="tiny", path=path)


# Per-rule wallclock: every aggregation rule through aggregate_tree, both
# impls.  The coordinate rules + Bulyan are the rows this trajectory
# tracks — the selection-network kernel (kernels/coord_stats) must keep
# them within ~2x of `mean` (ROADMAP target), vs the 20-100x gap of the
# jnp.sort references.
ALL_RULES = ("mean", "median", "trimmed_mean", "meamed", "phocas", "krum",
             "multi_krum", "bulyan", "pca", "geomed", "flag")
# rules whose n-sized stage the coord_stats kernel runs; the perf gate
# covers exactly these (the gram rules are gated by the rank-p solver
# sub-gate already).
COORD_GATED_RULES = ("median", "trimmed_mean", "meamed", "phocas", "bulyan")


def run_rules(p: int = 15, n: int = 100_000, *, f: int = 3, iters: int = 3,
              impls=("xla", "pallas"), section: str = "rules",
              path: Path | None = BENCH_JSON):
    """Wall-clock per (rule x impl) through ``aggregate_tree``.

    ``impl='pallas'`` is the production dispatch: on TPU it compiles the
    Pallas kernels; on a CPU host every stage falls back to its best XLA
    lowering (the fused selection network for the coordinate rules /
    Bulyan stage, the jnp references for the Gram stages) — never the
    interpreter, so the rows measure the real host path either way.
    """
    from repro.dist.aggregation import AggregatorConfig, aggregate_tree
    rng = np.random.default_rng(7)
    tree = jax.block_until_ready(_worker_tree(rng, p, n))
    records = []
    us_mean = {}
    for impl in impls:
        for name in ALL_RULES:
            cfg = AggregatorConfig(name=name, f=f, impl=impl)
            fn = jax.jit(lambda t, cfg=cfg: aggregate_tree(t, cfg)[0])
            us = time_call(fn, tree, iters=iters)
            if name == "mean":
                us_mean[impl] = us
            records.append({"rule": name, "impl": impl, "p": p, "n": n,
                            "us": round(us, 1),
                            "x_mean": round(us / max(us_mean[impl], 1e-9),
                                            2)})
            print(f"rule={name:13s} impl={impl:7s} {us:10.0f}us "
                  f"({records[-1]['x_mean']:.1f}x mean)")
    summary = {
        "coord_rule_x_mean": {
            impl: {r["rule"]: r["x_mean"] for r in records
                   if r["impl"] == impl and r["rule"] in COORD_GATED_RULES}
            for impl in impls},
        "note": ("x_mean = wallclock / the same impl's `mean` rule; the "
                 "selection network keeps the coordinate rules within ~2x "
                 "of mean where the jnp.sort refs sat 20-100x off "
                 "(XLA:CPU sorts with a scalar comparator)"),
    }
    payload = {"config": {"p": p, "n": n, "f": f, "iters": iters,
                          "impls": list(impls),
                          "backend": jax.default_backend()},
               "calibration_us": round(calibration_us(), 1),
               "records": records, "summary": summary}
    if path is not None:
        write_bench_json(section, payload, path)
    return payload


def run_rules_tiny(path: Path | None = BENCH_JSON):
    """CI perf-smoke config for the per-rule rows (seconds-scale)."""
    return run_rules(p=8, n=4096, f=1, iters=2, section="rules_tiny",
                     path=path)


def check_baseline(baseline_path: Path, *, factor: float = 2.0) -> int:
    """Gate: fresh tiny wall-clock vs the committed baseline.

    Two sub-gates, same machinery (committed numbers scaled by the
    machine-speed calibration ratio, fail on >``factor``x):

    * **rank-p solver** — the fresh ``tiny`` rank-p ``us_solver`` per
      (p, n) config vs the committed ``tiny`` section.
    * **coordinate rules** — the fresh ``rules_tiny`` pallas-impl
      wall-clock for the COORD_GATED_RULES vs the committed
      ``rules_tiny`` section (the selection-network path).
    """
    doc = json.loads(Path(baseline_path).read_text())
    base = doc.get("tiny")
    if not base:
        print(f"no 'tiny' baseline in {baseline_path}; nothing to gate "
              "against", file=sys.stderr)
        return 1
    fresh = run_tiny(path=None)
    scale = fresh["calibration_us"] / max(base["calibration_us"], 1e-9)
    failures = []
    for fr in fresh["records"]:
        if fr["solver"] != "rank_p" or fr["gram"] != "fused":
            continue
        br = next((r for r in base["records"]
                   if (r["p"], r["n"], r["solver"], r["gram"])
                   == (fr["p"], fr["n"], fr["solver"], fr["gram"])), None)
        if br is None:
            continue
        # gate on the solver stage: the gram/combine stages are sized by n
        # (tiny here) and dominated by allocator noise at smoke scale,
        # while us_solver is exactly the code path this PR optimizes.
        budget = factor * br["us_solver"] * scale
        status = "OK " if fr["us_solver"] <= budget else "FAIL"
        print(f"{status} rank_p p={fr['p']} n={fr['n']}: solver "
              f"{fr['us_solver']:.0f}us vs budget {budget:.0f}us "
              f"(baseline {br['us_solver']:.0f}us, calib x{scale:.2f}; "
              f"total {fr['us_total']:.0f}us)")
        if fr["us_solver"] > budget:
            failures.append(fr)

    base_rules = doc.get("rules_tiny")
    if not base_rules:
        print(f"no 'rules_tiny' baseline in {baseline_path}; the "
              "coordinate-rule gate has nothing to compare against",
              file=sys.stderr)
        return 1
    fresh_rules = run_rules_tiny(path=None)
    rscale = (fresh_rules["calibration_us"]
              / max(base_rules["calibration_us"], 1e-9))
    for fr in fresh_rules["records"]:
        if fr["impl"] != "pallas" or fr["rule"] not in COORD_GATED_RULES:
            continue
        br = next((r for r in base_rules["records"]
                   if (r["rule"], r["impl"]) == (fr["rule"], fr["impl"])),
                  None)
        if br is None:
            continue
        budget = factor * br["us"] * rscale
        status = "OK " if fr["us"] <= budget else "FAIL"
        print(f"{status} {fr['rule']} (pallas) p={fr['p']} n={fr['n']}: "
              f"{fr['us']:.0f}us vs budget {budget:.0f}us "
              f"(baseline {br['us']:.0f}us, calib x{rscale:.2f})")
        if fr["us"] > budget:
            failures.append(fr)

    if failures:
        print(f"perf-smoke: {len(failures)} tiny config(s) regressed "
              f">{factor}x vs committed baseline", file=sys.stderr)
        return 1
    print("perf-smoke: rank-p solver + coordinate-rule tiny wall-clock "
          "within budget")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (p in {4,8}, n=4096; also emits "
                         "the rules_tiny per-rule section)")
    ap.add_argument("--rules", action="store_true",
                    help="per-rule wallclock only (all 11 rules x "
                         "{xla, pallas} at p=15, n=1e5)")
    ap.add_argument("--check-baseline", metavar="JSON",
                    help="compare a fresh tiny run against the committed "
                         "baseline numbers; exit 1 on >2x regression "
                         "(rank-p solver + pallas coordinate rules)")
    ap.add_argument("--out", default=str(BENCH_JSON),
                    help="BENCH json path (default: repo root)")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)
    if args.check_baseline:
        return check_baseline(Path(args.check_baseline))
    if args.tiny:
        run_tiny(Path(args.out))
        run_rules_tiny(Path(args.out))
        return 0
    if args.rules:
        run_rules(iters=args.iters, path=Path(args.out))
        return 0
    run(iters=args.iters, path=Path(args.out))
    run_rules(iters=args.iters, path=Path(args.out))
    return 0


if __name__ == "__main__":
    sys.exit(main())
