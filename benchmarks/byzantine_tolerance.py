"""Paper Figs. 2 + 4: tolerance to the number of Byzantine workers.

Sweeps f = 0..3 (random-gradient Byzantine workers, p = 15) across all
aggregators; reports final test accuracy.  Fig. 2's claim (mean collapses
for any f >= 1) and Fig. 4's (FA stays highest as f grows) are both read
off this table.
"""

from __future__ import annotations

from benchmarks.common import ByzRunConfig, emit, run_byzantine_training

AGGS = ["mean", "trimmed_mean", "median", "meamed", "phocas",
        "multi_krum", "bulyan", "flag"]


def run(steps: int = 120, fs=(0, 1, 2, 3), aggs=AGGS):
    rows = [("name", "us_per_call", "derived")]
    for f in fs:
        for agg in aggs:
            cfg = ByzRunConfig(f=f, aggregator=agg, steps=steps,
                               attack="random", attack_kw={"scale": 5.0})
            out = run_byzantine_training(cfg)
            rows.append((f"byz_tolerance/{agg}/f={f}",
                         f"{out['us_per_step']:.0f}",
                         f"acc={out['final_accuracy']:.4f}"))
            print(rows[-1])
    emit(rows, "byzantine_tolerance")
    return rows


if __name__ == "__main__":
    run()
