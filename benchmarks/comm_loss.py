"""Communication efficiency under attack: codec x aggregator x attack.

The paper's headline claim is robustness *and* communication efficiency at
once; this benchmark measures the trade-off directly.  Every cell trains
the CNN task through ``repro.dist.aggregation.compressed_aggregate`` (the
same codec bridge the pod train step uses) and reports final accuracy next
to the codec's exact bits-saved ratio, so the derived column reads as a
bits-saved vs. accuracy curve per (aggregator, attack).

Rows are named ``comm/<codec>/<aggregator>/<attack>`` and are picked up by
``benchmarks/fill_experiments.py`` into the ``<!-- COMM_TABLE -->``
placeholder of EXPERIMENTS.md.  The paper's Fig. 6a operating point
(10% netem-style loss on f=3 links) is the ``drop`` attack column; the
Figs. 6b-d marginal-utility-of-workers sweep lives in
``benchmarks/scalability.py`` territory and keeps its historical rows here
under ``more_workers/`` so older EXPERIMENTS tables keep regenerating.
"""

from __future__ import annotations

from benchmarks.common import ByzRunConfig, emit, run_byzantine_training

CODECS = (
    ("none", {}),          # dense fp32 reference
    ("signsgd", {}),       # 1 bit/coord + per-row scale, EF on
    ("topk", {}),          # 1/16 of coords as (index, value), EF on
    ("countsketch", {}),   # Gram-feeding sketch, ratio 1/16
)


def run(steps: int = 100):
    rows = [("name", "us_per_call", "derived")]
    quick = steps <= 20
    aggs = ("flag", "mean") if quick else ("flag", "multi_krum", "mean")
    attks = ((("random", {"scale": 5.0}),) if quick else
             (("random", {"scale": 5.0}), ("sign_flip", {}),
              ("drop", {"loss_rate": 0.10})))
    for codec, ckw in CODECS:
        for agg in aggs:
            for attack, akw in attks:
                cfg = ByzRunConfig(f=3, aggregator=agg, steps=steps,
                                   attack=attack, attack_kw=akw,
                                   codec=codec, codec_kw=ckw)
                out = run_byzantine_training(cfg)
                rows.append((f"comm/{codec}/{agg}/{attack}",
                             f"{out['us_per_step']:.0f}",
                             f"acc={out['final_accuracy']:.4f} "
                             f"saved={out['comm_ratio']:.1f}x"))
                print(rows[-1])
    # Figs. 6b-d continuity: marginal utility of extra workers at fixed f.
    for p in ((9, 15) if quick else (9, 12, 15, 18)):
        for agg in ("flag", "multi_krum"):
            cfg = ByzRunConfig(p=p, f=3, aggregator=agg, steps=steps,
                               attack="random", attack_kw={"scale": 5.0})
            out = run_byzantine_training(cfg)
            rows.append((f"more_workers/{agg}/p={p}",
                         f"{out['us_per_step']:.0f}",
                         f"acc={out['final_accuracy']:.4f}"))
            print(rows[-1])
    emit(rows, "comm_loss")
    return rows


if __name__ == "__main__":
    run()
