"""Paper Fig. 6a: tolerance to communication loss (10% dropped gradients
on f=3 links, netem-style), plus Figs. 6b-d: marginal utility of extra
workers at fixed noise.
"""

from __future__ import annotations

from benchmarks.common import ByzRunConfig, run_byzantine_training, emit


def run(steps: int = 100):
    rows = [("name", "us_per_call", "derived")]
    # Fig 6a: 10% loss on 3 links
    for agg in (("flag", "multi_krum", "mean") if steps <= 20 else ("flag", "multi_krum", "bulyan", "mean", "median")):
        cfg = ByzRunConfig(f=3, aggregator=agg, steps=steps, attack="drop",
                           attack_kw={"loss_rate": 0.10})
        out = run_byzantine_training(cfg)
        rows.append((f"comm_loss/{agg}/drop10", f"{out['us_per_step']:.0f}",
                     f"acc={out['final_accuracy']:.4f}"))
        print(rows[-1])
    # Fig 6b-d: fixed f, growing p
    for p in ((9, 15) if steps <= 20 else (9, 12, 15, 18)):
        for agg in ("flag", "multi_krum"):
            cfg = ByzRunConfig(p=p, f=3, aggregator=agg, steps=steps,
                               attack="random", attack_kw={"scale": 5.0})
            out = run_byzantine_training(cfg)
            rows.append((f"more_workers/{agg}/p={p}",
                         f"{out['us_per_step']:.0f}",
                         f"acc={out['final_accuracy']:.4f}"))
            print(rows[-1])
    emit(rows, "comm_loss")
    return rows


if __name__ == "__main__":
    run()
