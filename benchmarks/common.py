"""Shared harness for the paper-figure benchmarks.

Each benchmark module reproduces one paper table/figure on the synthetic
image-classification task (the container is offline; see
data/synthetic.py).  The model is a small CNN (paper's MNIST setup uses
"two convolutional layers followed by two fully connected layers" — we
implement exactly that), trained with distributed-simulated workers:
per-worker minibatch gradients -> attack -> aggregator -> SGD, i.e. the
same Algorithm-1 pipeline as the pod train step, on one CPU device.

Output convention: every benchmark prints ``name,us_per_call,derived`` CSV
rows (plus a richer JSON dump under results/bench/).
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp

from repro.comm import CommConfig, dense_bits, get_codec, init_ef
from repro.core import FlagConfig, aggregators
from repro.core.attacks import apply_attack
from repro.data import augment as augment_lib
from repro.data.synthetic import SyntheticImages
from repro.dist.aggregation import AggregatorConfig, compressed_aggregate

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


# ---------------------------------------------------------------------------
# the paper's small CNN (2 conv + 2 fc)
# ---------------------------------------------------------------------------

def cnn_init(key, *, channels=3, num_classes=10, width=8):
    k = jax.random.split(key, 4)
    init = lambda kk, sh, fan: (jax.random.truncated_normal(kk, -2, 2, sh)
                                * (fan ** -0.5)).astype(jnp.float32)
    return {
        "c1": init(k[0], (3, 3, channels, width), 9 * channels),
        "c2": init(k[1], (3, 3, width, 2 * width), 9 * width),
        "f1": init(k[2], (8 * 8 * 2 * width, 64), 8 * 8 * 2 * width),
        "f2": init(k[3], (64, num_classes), 64),
        "b1": jnp.zeros((width,)), "b2": jnp.zeros((2 * width,)),
        "b3": jnp.zeros((64,)), "b4": jnp.zeros((num_classes,)),
    }


def cnn_logits(p, x):
    """x: (B, 32, 32, ch)."""
    y = jax.lax.conv_general_dilated(x, p["c1"], (1, 1), "SAME",
                                     dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b1"])
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    y = jax.lax.conv_general_dilated(y, p["c2"], (1, 1), "SAME",
                                     dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b2"])
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    y = y.reshape(y.shape[0], -1)
    y = jax.nn.relu(y @ p["f1"] + p["b3"])
    return y @ p["f2"] + p["b4"]


def cnn_loss(p, x, yl):
    lg = cnn_logits(p, x)
    return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(x.shape[0]), yl])


# ---------------------------------------------------------------------------
# Byzantine training driver
# ---------------------------------------------------------------------------

@dataclass
class ByzRunConfig:
    p: int = 15                        # workers (paper's main setting)
    f: int = 3                         # Byzantine workers
    # per-worker batch: the paper uses 128; the CPU-budget default here is
    # 16 (noted in EXPERIMENTS.md — relative aggregator orderings are
    # unchanged, and benchmarks/batch_size.py sweeps the batch explicitly).
    batch: int = 16
    steps: int = 60
    lr: float = 0.05
    momentum: float = 0.9
    lr_decay: float = 0.2              # paper: x0.2 ...
    lr_decay_every: int = 40           # ... every 10 epochs (scaled down)
    attack: str = "random"
    attack_kw: dict = field(default_factory=dict)
    aggregator: str = "flag"
    agg_kw: dict = field(default_factory=dict)
    flag_cfg: FlagConfig | None = None
    # worker->server compression (repro.comm).  codec != "none" routes the
    # aggregation through repro.dist.aggregation.compressed_aggregate (the
    # same bridge the pod train step uses): sketch codecs feed the Gram
    # path, biased codecs run through error feedback.  codec_kw maps onto
    # the remaining CommConfig fields (error_feedback, topk_density,
    # sketch_ratio, seed).
    codec: str = "none"
    codec_kw: dict = field(default_factory=dict)
    augment_scheme: str = "none"       # honest-worker augmentation
    augment_workers: int = 0
    gaussian_sigma: float = 0.0
    seed: int = 0
    eval_every: int = 20


def _flatten(tree):
    return jnp.concatenate([v.ravel() for v in jax.tree.leaves(tree)])


def _unflatten_like(tree, vec):
    leaves, td = jax.tree_util.tree_flatten(tree)
    out, i = [], 0
    for leaf in leaves:
        out.append(vec[i:i + leaf.size].reshape(leaf.shape))
        i += leaf.size
    return jax.tree_util.tree_unflatten(td, out)


def run_byzantine_training(cfg: ByzRunConfig, task: SyntheticImages | None = None):
    """Returns dict with accuracy trajectory + final accuracy + timing."""
    task = task or SyntheticImages(seed=cfg.seed)
    params = cnn_init(jax.random.PRNGKey(cfg.seed))
    mom = jnp.zeros_like(_flatten(params))
    xt, yt = task.test_set(1024)

    # FA-N (renormalized combine weights — beyond-paper, see
    # EXPERIMENTS.md §Repro): restores the update scale that
    # Algorithm 1's 1/p reconstruction shrinks.
    flag_cfg = cfg.flag_cfg or FlagConfig(lam=float(cfg.p), norm_mode="clip",
                                          renormalize=True)
    agg_fn = aggregators.get_aggregator(cfg.aggregator)
    agg_kw = dict(cfg.agg_kw)
    if cfg.aggregator == "flag":
        agg_kw.setdefault("cfg", flag_cfg)
    else:
        agg_kw.setdefault("f", cfg.f)
    comm_cfg = CommConfig(codec=cfg.codec, **cfg.codec_kw)
    agg_cfg = AggregatorConfig(name=cfg.aggregator, f=cfg.f, flag=flag_cfg)

    @partial(jax.jit, static_argnames=())
    def step_fn(params, mom, ef, key, lr):
        ks = jax.random.split(key, cfg.p + 2)
        xs, ys = jax.vmap(lambda k: task.sample(k, cfg.batch))(ks[:cfg.p])
        if cfg.augment_scheme != "none" and cfg.augment_workers > 0:
            w_idx = jnp.arange(cfg.p)
            xa = jax.vmap(lambda k, x: augment_lib.augment_batch(
                k, x, scheme=cfg.augment_scheme,
                gaussian_sigma=cfg.gaussian_sigma))(ks[:cfg.p], xs)
            sel = (w_idx >= cfg.f) & (w_idx < cfg.f + cfg.augment_workers)
            xs = jnp.where(sel[:, None, None, None, None], xa, xs)
        grads = jax.vmap(lambda x, y: _flatten(jax.grad(cnn_loss)(params, x, y))
                         )(xs, ys)
        grads = apply_attack(cfg.attack, grads, ks[-1], cfg.f,
                             **cfg.attack_kw)
        if cfg.codec != "none":
            # codecs see the per-leaf gradient tree (leaves (p, ...)) —
            # the same granularity the pod train step compresses at, so
            # e.g. signsgd gets per-row scales, not one scale per worker
            g_tree = jax.vmap(lambda v: _unflatten_like(params, v))(grads)
            d_tree, aux, ef = compressed_aggregate(
                g_tree, agg_cfg, comm_cfg,
                ef if comm_cfg.wants_ef else None)
            d = _flatten(d_tree)
        else:
            d = agg_fn(grads, **agg_kw)
        mom_n = cfg.momentum * mom + d
        params_n = jax.tree.map(lambda a, b: a - lr * b, params,
                                _unflatten_like(params, mom_n))
        return params_n, mom_n, ef

    @jax.jit
    def accuracy(params):
        return jnp.mean(jnp.argmax(cnn_logits(params, xt), -1) == yt)

    ef = (init_ef(params, cfg.p)
          if cfg.codec != "none" and comm_cfg.wants_ef else None)
    like = jax.eval_shape(lambda: init_ef(params, cfg.p))
    codec = get_codec(comm_cfg)
    comm_bits = codec.bits(like) if codec else dense_bits(like)
    comm_ratio = dense_bits(like) / comm_bits

    key = jax.random.PRNGKey(cfg.seed + 1)
    traj = []
    t0 = time.time()
    for t in range(cfg.steps):
        lr = cfg.lr * (cfg.lr_decay ** (t // cfg.lr_decay_every))
        key, k = jax.random.split(key)
        params, mom, ef = step_fn(params, mom, ef, k, lr)
        if (t + 1) % cfg.eval_every == 0 or t == cfg.steps - 1:
            traj.append((t + 1, float(accuracy(params))))
    wall = time.time() - t0
    return {"final_accuracy": traj[-1][1], "trajectory": traj,
            "wall_seconds": wall,
            "us_per_step": wall / cfg.steps * 1e6,
            "comm_bits_per_step": float(comm_bits),
            "comm_ratio": float(comm_ratio)}


def emit(rows, name):
    """Print CSV rows + persist JSON."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for r in rows:
        print(",".join(str(x) for x in r))
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as fh:
        json.dump(rows, fh, indent=1, default=float)
