"""Inject benchmark + dry-run results into EXPERIMENTS.md placeholders."""

from __future__ import annotations

import glob
import json


def repro_table():
    rows = []
    for path in sorted(glob.glob("results/bench/*.json")):
        with open(path) as f:
            for r in json.load(f):
                if (isinstance(r, list) and len(r) == 3
                        and "acc=" in str(r[2])
                        and not str(r[0]).startswith("comm/")):
                    rows.append(tuple(r))
    if not rows:
        return "*(benchmarks still running — see bench_output.txt)*"
    lines = ["| benchmark | us/step | result |", "|---|---|---|"]
    for name, us, derived in rows:
        lines.append(f"| {name} | {us} | {derived} |")
    return "\n".join(lines)


def comm_table():
    """Bits-saved vs. accuracy rows from benchmarks/comm_loss.py."""
    rows = []
    for path in sorted(glob.glob("results/bench/comm_loss.json")):
        with open(path) as f:
            for r in json.load(f):
                if isinstance(r, list) and str(r[0]).startswith("comm/"):
                    rows.append(tuple(r))
    if not rows:
        return ("*(run `PYTHONPATH=src python -m benchmarks.run "
                "--only comm_loss` to fill)*")
    lines = ["| codec / aggregator / attack | us/step | accuracy, "
             "bits saved |", "|---|---|---|"]
    for name, us, derived in rows:
        lines.append(f"| {name[len('comm/'):]} | {us} | {derived} |")
    return "\n".join(lines)


def churn_table():
    """Membership-churn rows from benchmarks/membership_churn.py."""
    rows = []
    for path in sorted(glob.glob("results/bench/membership_churn.json")):
        with open(path) as f:
            for r in json.load(f):
                if isinstance(r, list) and str(r[0]).startswith("churn/"):
                    rows.append(tuple(r))
    if not rows:
        return ("*(run `PYTHONPATH=src python -m "
                "benchmarks.membership_churn` to fill)*")
    lines = ["| fault scenario / aggregator | us/step | final loss, "
             "active workers, compiles |", "|---|---|---|"]
    for name, us, derived in rows:
        lines.append(f"| {name[len('churn/'):]} | {us} | {derived} |")
    return "\n".join(lines)


def dryrun_summary():
    singles, multis, fails = [], [], []
    for path in sorted(glob.glob("results/dryrun/*.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("variant_tag"):
            continue
        if not r.get("ok"):
            fails.append(f"{r.get('arch')}/{r.get('shape')}/{r.get('mesh')}")
        elif r["mesh"] == "16x16":
            singles.append(r)
        else:
            multis.append(r)
    lines = [
        f"* single-pod (16×16, 256 chips): **{len(singles)}/40 combinations "
        "lower + compile OK** (full roofline table below).",
        f"* multi-pod (2×16×16, 512 chips): **{len(multis)}/40 OK** — the "
        "pod axis shards the worker/batch dims; remaining combinations "
        "regenerate with the same harness "
        "(`--mesh multi`; compile-bound on this 1-core host).",
    ]
    if fails:
        lines.append(f"* failures: {fails}")
    else:
        lines.append("* zero lowering/compile failures across all attempted "
                     "combinations.")
    done_multi = sorted({(r['arch'], r['shape']) for r in multis})
    if done_multi:
        lines.append("* multi-pod combos completed in-session: "
                     + ", ".join(f"{a}×{s}" for a, s in done_multi) + ".")
    return "\n".join(lines)


def notes():
    out = []
    for path in sorted(glob.glob("results/dryrun/*_single.json")):
        with open(path) as f:
            r = json.load(f)
        if not r.get("ok") or r.get("variant_tag"):
            continue
        kinds = r["collectives"]["per_kind_bytes"]
        if not kinds:
            continue
        top = max(kinds.items(), key=lambda kv: kv[1])
        out.append((r["arch"], r["shape"], top[0], top[1]))
    agg = {}
    for arch, shape, kind, b in out:
        agg.setdefault(kind, []).append((b, f"{arch}/{shape}"))
    lines = []
    for kind, items in sorted(agg.items()):
        items.sort(reverse=True)
        tops = ", ".join(f"{n} ({b/1e9:.1f}GB)" for b, n in items[:3])
        lines.append(f"* **{kind}**-heaviest: {tops}")
    return "\n".join(lines)


def main():
    with open("EXPERIMENTS.md") as f:
        s = f.read()
    s = s.replace("<!-- REPRO_TABLE -->", repro_table())
    s = s.replace("<!-- COMM_TABLE -->", comm_table())
    s = s.replace("<!-- CHURN_TABLE -->", churn_table())
    s = s.replace("**(table filled from results/bench — see PLACEHOLDER "
                  "markers)**", "")
    s = s.replace("<!-- DRYRUN_TABLE -->", dryrun_summary())
    s = s.replace("<!-- DRYRUN_NOTES -->", notes())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(s)
    print("filled EXPERIMENTS.md")


if __name__ == "__main__":
    main()
