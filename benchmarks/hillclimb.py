"""§Perf hillclimb driver: named dry-run variants for the three chosen
(arch × shape) pairs, each encoding one hypothesis from EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.hillclimb --run <variant> [...]

Variants re-lower with modified knobs (sharding rules / dtypes / ZeRO /
Gram sketch / microbatching) and write results/dryrun/<combo>_<tag>.json,
which the §Perf tables diff against the baselines.
"""

from __future__ import annotations

import argparse
import json
import os


VARIANTS = {
    # H-A: smollm-360m x train_4k — collective-bound baseline (TP of a 360M
    # model over 16 chips makes activation all-reduces dominate).
    "smollm_dp": dict(
        arch="smollm-360m", shape="train_4k",
        kwargs=dict(extra_rules={"sub_batch": "model", "mlp": None,
                                 "qkv": None, "heads": None, "vocab": None,
                                 "state": None},
                    gram_dtype="bfloat16", sketch_stride=8),
        hypothesis="replicate params, shard the per-worker batch over the "
                   "model axis (pure DP): activation ARs vanish; grads AR "
                   "2x1.45GB; FA Gram sketched bf16 ~0.7GB"),
    "smollm_dp_nosketch": dict(
        arch="smollm-360m", shape="train_4k",
        kwargs=dict(extra_rules={"sub_batch": "model", "mlp": None,
                                 "qkv": None, "heads": None, "vocab": None,
                                 "state": None}),
        hypothesis="same but full fp32 Gram: isolates the sketch's "
                   "contribution to the collective term"),
    "smollm_sketch": dict(
        arch="smollm-360m", shape="train_4k",
        kwargs=dict(gram_dtype="bfloat16", sketch_stride=8),
        hypothesis="baseline sharding, sketched bf16 Gram only"),
    # H-B: mixtral-8x7b x train_4k — memory-dominated baseline.
    "mixtral_mem": dict(
        arch="mixtral-8x7b", shape="train_4k",
        kwargs=dict(zero1=True, gram_dtype="bfloat16", microbatch=16,
                    sketch_stride=8),
        hypothesis="ZeRO-1 momentum (11.7->0.7GB), microbatch 16 "
                   "(activations /4), bf16 sketched Gram (grad copies /8)"),
    "mixtral_zero1": dict(
        arch="mixtral-8x7b", shape="train_4k",
        kwargs=dict(zero1=True),
        hypothesis="ZeRO-1 only: isolates optimizer-state sharding"),
    "mixtral_fsdp": dict(
        arch="mixtral-8x7b", shape="train_4k",
        kwargs=dict(extra_rules={"sub_batch": "model"}, zero1=True,
                    gram_dtype="bfloat16"),
        hypothesis="FSDP-style: shard the per-worker batch over model while "
                   "params stay model-sharded -> XLA gathers weights per "
                   "layer (93GB bf16/microbatch) instead of all-reducing "
                   "activations+MoE buffers (~4.4TB); activations /16"),
    # H-C: command-r-35b x decode_32k — biggest-cache decode.
    "commandr_decode_seqshard": dict(
        arch="command-r-35b", shape="decode_32k",
        kwargs=dict(extra_rules={"head_dim": None, "cache_seq": "model"}),
        hypothesis="baseline AGs the head_dim-sharded cache per layer "
                   "(42.8GB/token). Shard the cache SEQUENCE dim over model "
                   "instead: attention reduces over the sharded seq axis "
                   "(psum of (B,h,1) partials ~KBs), cache stays resident; "
                   "predict collective term -> ~0.1GB (params/logits ARs)"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", nargs="+", required=True,
                    help=f"variants: {sorted(VARIANTS)} or 'all'")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)
    names = sorted(VARIANTS) if args.run == ["all"] else args.run

    from repro.launch.dryrun import lower_one   # sets XLA_FLAGS first
    os.makedirs(args.out, exist_ok=True)
    for name in names:
        v = VARIANTS[name]
        print(f"[{name}] {v['hypothesis']}", flush=True)
        res = lower_one(v["arch"], v["shape"], multi_pod=False, **v["kwargs"])
        res["variant_tag"] = name
        res["hypothesis"] = v["hypothesis"]
        path = os.path.join(args.out,
                            f"{v['arch']}_{v['shape']}_single_{name}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1, default=float)
        print(f"[{name}] peak={res['memory']['peak_bytes']/1e9:.1f}GB "
              f"coll={res['collectives']['total_moved_bytes_per_device']/1e9:.1f}GB "
              f"flops={res.get('flops_corrected_per_device', 0):.2e}",
              flush=True)


if __name__ == "__main__":
    main()
