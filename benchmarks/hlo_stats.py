"""Re-export shim: the HLO parsing substrate moved to repro.analysis.hlo.

``benchmarks/`` sits *above* ``src/repro`` in the layer map, so the
parser the dry-run roofline imports could never live here — it now does
not (see docs/static_analysis.md).  Bench scripts keep importing
``benchmarks.hlo_stats`` unchanged through this shim.
"""

from __future__ import annotations

from repro.analysis.hlo import (  # noqa: F401
    DTYPE_BYTES,
    CollectiveStats,
    HloCost,
    parse_collectives,
    parse_cost,
    shape_dims,
)

__all__ = ["DTYPE_BYTES", "CollectiveStats", "HloCost", "parse_collectives",
           "parse_cost", "shape_dims"]
