"""Paper Figs. 8 + 11: the data-dependent regularizer lambda interpolates
FA toward Multi-Krum/Bulyan.

p=7, f=1 (paper's Fig. 8 setting, satisfies p >= 4f+3); sweeps lambda and
reports (a) final accuracy, (b) cosine similarity between FA's aggregate
and Multi-Krum's on identical gradients (Fig. 11's metric).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (ByzRunConfig, _flatten, cnn_init, cnn_loss,
                               emit, run_byzantine_training)
from repro.core import FlagConfig, aggregators
from repro.core.attacks import apply_attack
from repro.data.synthetic import SyntheticImages


def cosine_similarity_probe(lam: float, p=7, f=1, probes=16, seed=0):
    task = SyntheticImages(seed=seed)
    params = cnn_init(jax.random.PRNGKey(seed))
    sims = []
    key = jax.random.PRNGKey(seed + 7)
    for t in range(probes):
        key, k = jax.random.split(key)
        ks = jax.random.split(k, p + 1)
        grads = []
        for i in range(p):
            x, y = task.sample(ks[i], 64)
            grads.append(_flatten(jax.grad(cnn_loss)(params, x, y)))
        G = jnp.stack(grads)
        G = apply_attack("random", G, ks[-1], f, scale=5.0)
        d_fa = aggregators.flag(G, cfg=FlagConfig(lam=lam, norm_mode="clip"))
        d_mk = aggregators.multi_krum(G, f=f)
        sims.append(float(jnp.vdot(d_fa, d_mk)
                          / (jnp.linalg.norm(d_fa) * jnp.linalg.norm(d_mk)
                             + 1e-30)))
    return float(np.mean(sims))


def run(steps: int = 100, lams=(0.1, 1.0, 3.0, 7.0, 21.0)):
    rows = [("name", "us_per_call", "derived")]
    for lam in lams:
        cfg = ByzRunConfig(p=7, f=1, aggregator="flag", steps=steps,
                           attack="random", attack_kw={"scale": 5.0},
                           flag_cfg=FlagConfig(lam=lam, norm_mode="clip"))
        out = run_byzantine_training(cfg)
        cos = cosine_similarity_probe(lam)
        rows.append((f"lambda/{lam}", f"{out['us_per_step']:.0f}",
                     f"acc={out['final_accuracy']:.4f};cos_mk={cos:.4f}"))
        print(rows[-1])
    emit(rows, "lambda_sweep")
    return rows


if __name__ == "__main__":
    run()
