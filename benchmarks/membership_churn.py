"""Membership churn under training: fault scenario x aggregator sweep.

Workers leaving and joining mid-run is the system-level failure mode the
elastic layer (repro.dist.membership) adds on top of the Byzantine threat
models.  Every cell trains the reduced LM through the *real* distributed
train step with a ``TrainConfig.faults`` schedule — crash / leave+rejoin /
rolling churn / periodic stragglers — and reports the final loss next to
the mean active-worker count and the *compile count* (membership is a
traced function of the step index, so every cell must compile exactly
once; the sweep asserts it).

Rows are named ``churn/<scenario>/<aggregator>`` and are picked up by
``benchmarks/fill_experiments.py`` into the ``<!-- CHURN_TABLE -->``
placeholder of EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.membership_churn        # full
    PYTHONPATH=src python -m benchmarks.membership_churn 12     # quick
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.analysis import cache_size
from repro.configs import get_config, reduce_for_smoke
from repro.core.flag import FlagConfig
from repro.data.pipeline import WorkerDataConfig, lm_worker_batches
from repro.data.synthetic import SyntheticLM
from repro.dist.aggregation import AggregatorConfig
from repro.dist.membership import get_fault_schedule
from repro.dist.train_step import TrainConfig, build_train_step, init_train_state
from repro.optim import adamw, warmup_cosine

W = 8
SCENARIOS = (
    ("none", {}),
    ("crash", {"n": 2, "at": 10}),
    ("rejoin", {"n": 2, "at": 8, "down": 10}),
    ("churn", {"period": 4}),
    ("straggle", {"n": 2, "every": 8, "duration": 3}),
)


def _one(scenario: str, kw: dict, agg: str, steps: int):
    cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
        frontend=None, num_prefix_embeds=0)
    sched_kw = dict(kw)
    if scenario in ("churn", "straggle"):
        sched_kw["horizon"] = steps
    tc = TrainConfig(
        aggregator=AggregatorConfig(
            name=agg, f=2, flag=FlagConfig(lam=0.0, regularizer="none")),
        attack="sign_flip", attack_f=1,
        faults=get_fault_schedule(scenario, W, **sched_kw))
    opt = adamw(weight_decay=0.0)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step_fn = jax.jit(build_train_step(
        cfg, tc, opt, warmup_cosine(3e-3, steps, warmup=min(5, steps // 4))))
    task = SyntheticLM(vocab_size=cfg.vocab_size)
    wdc = WorkerDataConfig(workers=W, per_worker_batch=2)
    active, loss = [], None
    t0 = time.time()
    for t in range(steps):
        batch = lm_worker_batches(task, wdc, t, 32)
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jax.random.PRNGKey(t),
                                       jnp.asarray(t, jnp.int32))
        loss = float(m["loss"])
        active.append(int(m.get("active_workers", W)))
    wall = time.time() - t0
    compiles = cache_size(step_fn)
    assert compiles == 1, (
        f"membership changes must not recompile: {scenario}/{agg} "
        f"compiled {compiles}x")
    return {"final_loss": loss, "mean_active": sum(active) / len(active),
            "min_active": min(active), "us_per_step": wall / steps * 1e6,
            "compiles": compiles}


def run(steps: int = 40, aggs=("flag", "krum", "mean", "median")):
    rows = [("name", "us_per_call", "derived")]
    for scenario, kw in SCENARIOS:
        for agg in aggs:
            out = _one(scenario, kw, agg, steps)
            rows.append((f"churn/{scenario}/{agg}",
                         f"{out['us_per_step']:.0f}",
                         f"loss={out['final_loss']:.4f} "
                         f"act={out['mean_active']:.1f}/{W} "
                         f"(min {out['min_active']}) "
                         f"compiles={out['compiles']}"))
            print(rows[-1])
    emit(rows, "membership_churn")
    return rows


if __name__ == "__main__":
    run(int(sys.argv[1]) if len(sys.argv) > 1 else 40)
