"""Paper Fig. 12 (appendix E.2): Fall-of-Empires (IPM), 10x sign-flip, and
the PCA top-m baseline, p=15, f=2."""

from __future__ import annotations

from benchmarks.common import ByzRunConfig, emit, run_byzantine_training


def run(steps: int = 100):
    rows = [("name", "us_per_call", "derived")]
    for attack, kw in (("ipm", {"eps": 0.1}), ("sign_flip", {"scale": 10.0}),
                       ("alie", {"z": 1.5})):
        for agg in (("flag", "pca", "mean") if steps <= 20 else ("flag", "pca", "multi_krum", "bulyan", "mean")):
            cfg = ByzRunConfig(f=2, aggregator=agg, steps=steps,
                               attack=attack, attack_kw=kw)
            out = run_byzantine_training(cfg)
            rows.append((f"attack/{attack}/{agg}",
                         f"{out['us_per_step']:.0f}",
                         f"acc={out['final_accuracy']:.4f}"))
            print(rows[-1])
    emit(rows, "other_attacks")
    return rows


if __name__ == "__main__":
    run()
