"""Roofline analysis: three terms per (arch x shape) from the dry-run JSONs.

    compute_s    = FLOPs_per_device / peak_FLOPs_per_chip
    memory_s     = HBM_bytes_per_device / HBM_bw
    collective_s = collective_bytes_per_device / ICI_link_bw

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
(The spec's formulas divide global quantities by `chips x peak`; our HLO
numbers are already per-device — SPMD modules have per-device shapes — so
we divide by single-chip peaks, which is the same quantity.)

FLOPs and HBM bytes are the **loop-corrected** values from
repro.analysis.hlo.parse_cost (XLA's cost_analysis counts while bodies
once — both raw and corrected are recorded for transparency).  MODEL_FLOPS
uses the standard 6*N*D (train) / 2*N*D (inference forward) with N =
active params (MoE counts top-k + shared).

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
      [--mesh 16x16] [--csv out.csv]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

CHIPS = {"16x16": 256, "pod2x16x16": 512}


def model_flops(rec: dict) -> float:
    """Analytic useful FLOPs per device for the step that was lowered."""
    n_active = rec.get("active_param_count", 0)
    chips = CHIPS.get(rec["mesh"], 256)
    shape = rec["shape"]
    kind = rec["kind"]
    tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
              "decode_32k": 128, "long_500k": 1}[shape]
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n_active * tokens / chips


def load(dirname: str, mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("variant_tag"):
            continue              # hillclimb variants live in §Perf, not here
        if not r.get("ok"):
            recs.append(r)
            continue
        if mesh and r["mesh"] != mesh:
            continue
        recs.append(r)
    return recs


def analyze(rec: dict) -> dict:
    if not rec.get("ok"):
        return {"arch": rec.get("arch"), "shape": rec.get("shape"),
                "mesh": rec.get("mesh"), "ok": False,
                "error": rec.get("error", "")[:120]}
    flops = rec.get("flops_corrected_per_device") or rec["flops_per_device"]
    hbm = rec.get("hbm_bytes_corrected_per_device") \
        or rec["bytes_accessed_per_device"]
    coll = rec["collectives"]["total_moved_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(rec)
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", ""), "ok": True,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "bound_s": max(t_c, t_m, t_x),
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / flops if flops else 0.0,
        "peak_gb": rec["memory"]["peak_bytes"] / 1e9,
        "fits_16gb": rec["memory"]["peak_bytes"] < 16e9,
    }
    # one-line "what would move the dominant term down"
    hints = {
        "compute": "raise MXU utilization (larger per-step tiles, bf16 "
                   "throughout) or cut redundant recompute (remat policy)",
        "memory": "shard the fat dim (ZeRO-1 opt state / bf16 params / "
                  "KV-cache sharding) and fuse the streaming ops",
        "collective": "cut TP activation all-reduces (sequence-parallel or "
                      "batch-over-model for small d_model) and sketch the "
                      "FA Gram all-gather",
    }
    out["hint"] = hints[dom]
    return out


def table(rows, keys=("arch", "shape", "mesh", "variant", "compute_s",
                      "memory_s", "collective_s", "dominant",
                      "useful_flops_ratio", "peak_gb")):
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)
    widths = [max(len(k), max((len(fmt(r.get(k, ""))) for r in rows),
                              default=0)) for k in keys]
    lines = ["  ".join(k.ljust(w) for k, w in zip(keys, widths))]
    for r in rows:
        lines.append("  ".join(fmt(r.get(k, "")).ljust(w)
                               for k, w in zip(keys, widths)))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16",
                    help="roofline mesh (single pod per spec); 'all' for both")
    ap.add_argument("--csv", default="results/roofline.csv")
    args = ap.parse_args(argv)
    recs = load(args.dir, None if args.mesh == "all" else args.mesh)
    rows = [analyze(r) for r in recs]
    ok_rows = [r for r in rows if r.get("ok")]
    print(table(ok_rows))
    bad = [r for r in rows if not r.get("ok")]
    for r in bad:
        print(f"FAILED: {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")
    if args.csv:
        os.makedirs(os.path.dirname(args.csv) or ".", exist_ok=True)
        keys = list(ok_rows[0].keys()) if ok_rows else []
        with open(args.csv, "w") as f:
            f.write(",".join(keys) + "\n")
            for r in ok_rows:
                f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
        print(f"\nwrote {args.csv} ({len(ok_rows)} rows, {len(bad)} failures)")
    return rows


if __name__ == "__main__":
    main()
