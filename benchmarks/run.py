"""Benchmark entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only name,...]``

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py) and persists
JSON under results/bench/.  ``--quick`` shrinks step counts so the full
suite finishes in CI time; the EXPERIMENTS.md numbers use the defaults.
"""

from __future__ import annotations

import argparse
import time

from benchmarks import (augmentation, batch_size, byzantine_tolerance,
                        comm_loss, lambda_sweep, membership_churn,
                        other_attacks, scalability, wallclock)

SUITES = {
    "byzantine_tolerance": lambda q: byzantine_tolerance.run(
        steps=20 if q else 40, fs=(1, 3) if q else (0, 1, 2, 3),
        aggs=("mean", "multi_krum", "flag") if q
        else byzantine_tolerance.AGGS),
    "batch_size": lambda q: batch_size.run(
        steps=20 if q else 35, batches=(32, 128) if q else (32, 64, 128, 256),
        aggs=("flag", "multi_krum") if q else ("flag", "multi_krum",
                                               "bulyan", "median")),
    "comm_loss": lambda q: comm_loss.run(steps=20 if q else 35),
    "augmentation": lambda q: augmentation.run(steps=20 if q else 35),
    "lambda_sweep": lambda q: lambda_sweep.run(
        steps=20 if q else 35, lams=(0.1, 7.0) if q else
        (0.1, 1.0, 3.0, 7.0, 21.0)),
    "wallclock": lambda q: wallclock.run(
        ns=(10_000, 100_000) if q else (10_000, 100_000, 1_000_000)),
    "membership_churn": lambda q: membership_churn.run(
        steps=16 if q else 40,
        aggs=("flag", "krum", "mean") if q
        else ("flag", "krum", "mean", "median")),
    "other_attacks": lambda q: other_attacks.run(steps=20 if q else 35),
    "scalability": lambda q: scalability.run(steps=10 if q else 25),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        print(f"# === {name} ===", flush=True)
        SUITES[name](args.quick)
    print(f"# total_wall_seconds,{time.time() - t0:.0f},")


if __name__ == "__main__":
    main()
