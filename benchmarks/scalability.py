"""Paper Fig. 9: scaling out to p = 60 workers (up to f = 14).

The paper demonstrates FA remains feasible at p=60 (their CNN/MNIST
setup); we run the same-shape CNN on the synthetic task and also record
the aggregation-call cost at p=60 (q = 60 + 1770 pairwise columns)."""

from __future__ import annotations

from benchmarks.common import ByzRunConfig, emit, run_byzantine_training


def run(steps: int = 60):
    rows = [("name", "us_per_call", "derived")]
    for p, f in (((30, 7),) if steps <= 10 else ((30, 7), (60, 14))):
        for agg in (("flag", "mean") if steps <= 10 else ("flag", "multi_krum", "mean")):
            cfg = ByzRunConfig(p=p, f=f, batch=32, aggregator=agg,
                               steps=steps, attack="random",
                               attack_kw={"scale": 5.0})
            out = run_byzantine_training(cfg)
            rows.append((f"scale/{agg}/p={p},f={f}",
                         f"{out['us_per_step']:.0f}",
                         f"acc={out['final_accuracy']:.4f}"))
            print(rows[-1])
    emit(rows, "scalability")
    return rows


if __name__ == "__main__":
    run()
