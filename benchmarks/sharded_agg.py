"""Sharded-aggregation benchmark: devices x n sweep of the mesh-native path.

Times a full FA/mean aggregation (Gram + weights + combine) through
``aggregate_tree(..., sharded=mesh)`` against the single-device path,
sweeping devices in {1, 2, 4, 8} (forced host CPU devices) x n in
{1e5, 1e6} coordinates.  Rows land in the shared ``BENCH_aggregator.json``
under the ``sharded_agg`` section.

On one physical CPU the forced 8-"device" mesh is an *emulation* — every
shard still executes on the same silicon, so wall-clock measures the
dataflow overhead (shard_map dispatch, the (W, W) psum), not the n/d
speedup a real mesh delivers.  The structural win is asserted separately:
``tests/test_sharded_agg.py`` checks the compiled per-device HLO never
holds a full-width coordinate tensor, which is what makes the path scale
on hardware where the devices are real.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/sharded_agg.py

(The flag is set automatically when the script is the main module and no
device-count flag is present.)
"""

from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

if __name__ == "__main__":
    # Script mode only (importers keep their own device topology): must
    # happen before the first jax import — the host platform reads
    # XLA_FLAGS once at backend initialization.
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()

if __package__ in (None, ""):
    # `python benchmarks/sharded_agg.py` puts benchmarks/ itself on
    # sys.path; the sibling imports below need the repo root.
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_aggregator import (BENCH_JSON, calibration_us,
                                         time_call, write_bench_json)
from repro.core.flag import FlagConfig
from repro.dist.aggregation import AggregatorConfig, aggregate_tree
from repro.launch.mesh import make_host_mesh


def _worker_tree(rng, p: int, n: int, leaves: int = 6):
    sizes = [n // leaves] * (leaves - 1)
    sizes.append(n - sum(sizes))
    return {f"leaf{i}": jnp.asarray(rng.normal(size=(p, s)), jnp.float32)
            for i, s in enumerate(sizes)}


def run(devices=(1, 2, 4, 8), ns=(100_000, 1_000_000), rules=("flag", "mean"),
        *, p: int = 16, iters: int = 3, section: str = "sharded_agg",
        path: Path | None = BENCH_JSON):
    avail = len(jax.devices())
    devices = [d for d in devices if d <= avail]
    records = []
    for n in ns:
        rng = np.random.default_rng(n % 99991)
        tree = jax.block_until_ready(_worker_tree(rng, p, n))
        for rule in rules:
            cfg = AggregatorConfig(
                name=rule, flag=FlagConfig(lam=float(p), m=4, tol=0.0))
            us_single = time_call(
                jax.jit(lambda t, c=cfg: aggregate_tree(t, c)[0]), tree,
                iters=iters)
            for d in devices:
                mesh = make_host_mesh(d)
                us_sharded = time_call(
                    jax.jit(lambda t, c=cfg, m=mesh: aggregate_tree(
                        t, c, sharded=m)[0]), tree, iters=iters)
                records.append({
                    "devices": d, "n": n, "p": p, "rule": rule,
                    "us_sharded": round(us_sharded, 1),
                    "us_single": round(us_single, 1),
                    "overhead_x": round(us_sharded / us_single, 3),
                })
                print(f"rule={rule} n={n} devices={d}: "
                      f"sharded={us_sharded:.0f}us "
                      f"single={us_single:.0f}us "
                      f"({us_sharded / us_single:.2f}x)")
    payload = {
        "config": {"devices": list(devices), "ns": list(ns), "p": p,
                   "rules": list(rules), "iters": iters,
                   "backend": jax.default_backend(),
                   "forced_host_devices": avail},
        "calibration_us": round(calibration_us(), 1),
        "records": records,
        "note": ("forced host devices share one CPU: us_sharded measures "
                 "shard_map + psum dataflow overhead, not a real-mesh "
                 "speedup; per-device memory/HLO scaling is asserted in "
                 "tests/test_sharded_agg.py"),
    }
    if path is not None:
        write_bench_json(section, payload, path)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=str(BENCH_JSON))
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (n=16384 only, 2 iters)")
    args = ap.parse_args(argv)
    if args.tiny:
        run(ns=(16_384,), iters=2, path=Path(args.out))
        return 0
    run(iters=args.iters, path=Path(args.out))
    return 0


if __name__ == "__main__":
    sys.exit(main())
