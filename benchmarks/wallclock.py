"""Paper Fig. 10: wall-clock time of the aggregation call itself.

Times each aggregator on realistic gradient-matrix sizes (p=15, n up to
1M coordinates) — the paper's complexity discussion (Sec. 4) made FA's
per-iteration cost the headline limitation; the Gram-space form keeps it
O(n p^2) with a tiny O(q^3) eigh.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FlagConfig, aggregators
from benchmarks.common import emit


def time_call(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else         jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run(p: int = 15, ns=(10_000, 100_000, 1_000_000)):
    rows = [("name", "us_per_call", "derived")]
    rng = np.random.default_rng(0)
    for n in ns:
        G = jnp.asarray(rng.normal(size=(p, n)).astype(np.float32))
        for agg in ("mean", "median", "trimmed_mean", "multi_krum",
                    "bulyan", "flag"):
            fn = aggregators.get_aggregator(agg)
            kw = ({"cfg": FlagConfig(lam=float(p))} if agg == "flag"
                  else {"f": 3})
            jfn = jax.jit(lambda g: fn(g, **kw))
            us = time_call(jfn, G)
            rows.append((f"wallclock/{agg}/n={n}", f"{us:.0f}",
                         f"p={p}"))
            print(rows[-1])
    emit(rows, "wallclock")
    return rows


if __name__ == "__main__":
    run()
