"""Paper Fig. 10: wall-clock time of the aggregation call itself.

Times each aggregator on realistic gradient-matrix sizes (p=15, n up to
1M coordinates) — the paper's complexity discussion (Sec. 4) made FA's
per-iteration cost the headline limitation; the Gram-space rank-p form
keeps it O(n p^2) with a tiny O(p^3)-per-iteration solve.

Timing goes through :func:`benchmarks.bench_aggregator.time_call` (single
synchronized warm-up, then a ``time.perf_counter`` loop) and the rows land
both in the CSV/``results/bench`` emit and in the shared
``BENCH_aggregator.json`` trajectory (section ``wallclock``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_aggregator import (calibration_us, time_call,
                                         write_bench_json)
from benchmarks.common import emit
from repro.core import FlagConfig, aggregators


def run(p: int = 15, ns=(10_000, 100_000, 1_000_000)):
    rows = [("name", "us_per_call", "derived")]
    records = []
    rng = np.random.default_rng(0)
    for n in ns:
        G = jnp.asarray(rng.normal(size=(p, n)).astype(np.float32))
        for agg in ("mean", "median", "trimmed_mean", "multi_krum",
                    "bulyan", "flag"):
            fn = aggregators.get_aggregator(agg)
            kw = ({"cfg": FlagConfig(lam=float(p))} if agg == "flag"
                  else {"f": 3})
            jfn = jax.jit(lambda g: fn(g, **kw))
            us = time_call(jfn, G)
            rows.append((f"wallclock/{agg}/n={n}", f"{us:.0f}",
                         f"p={p}"))
            records.append({"aggregator": agg, "p": p, "n": n,
                            "us_per_call": round(us, 1)})
            print(rows[-1])
    emit(rows, "wallclock")
    write_bench_json("wallclock", {"calibration_us": round(calibration_us(), 1),
                                   "records": records})
    return rows


if __name__ == "__main__":
    run()
