"""The paper\'s nonlinear augmentations on the synthetic image task.

Shows Lotka-Volterra (RK4) and Arnold\'s Cat Map (exact + smooth) transforms
and the gradient divergence they induce across workers — the dependent-
noise regime FA targets (paper Sec. 3.1).

    PYTHONPATH=src python examples/augmentation_demo.py
"""

import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import _flatten, cnn_init, cnn_loss
from repro.data import augment
from repro.data.synthetic import SyntheticImages

task = SyntheticImages(seed=0)
x, y = task.sample(jax.random.PRNGKey(0), 64)
print(f"clean images: shape={x.shape} range=[{float(x.min()):.2f}, "
      f"{float(x.max()):.2f}]")

for name, fn in [("lotka_volterra", augment.lotka_volterra),
                 ("cat_map", augment.cat_map),
                 ("smooth_cat_map", augment.smooth_cat_map)]:
    xa = fn(x)
    delta = float(jnp.mean(jnp.abs(xa - x)))
    print(f"{name:16s} mean|delta|={delta:.4f}")

# gradient divergence: cosine between clean-worker and augmented-worker grads
params = cnn_init(jax.random.PRNGKey(1))
g_clean = _flatten(jax.grad(cnn_loss)(params, x, y))
for name, fn in [("lotka_volterra", augment.lotka_volterra),
                 ("cat_map", augment.cat_map)]:
    g_aug = _flatten(jax.grad(cnn_loss)(params, fn(x), y))
    cos = float(jnp.vdot(g_clean, g_aug)
                / (jnp.linalg.norm(g_clean) * jnp.linalg.norm(g_aug)))
    print(f"grad cosine clean vs {name:16s}: {cos:.4f}")
