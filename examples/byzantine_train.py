"""End-to-end driver: train a language model with FA under Byzantine attack.

Defaults run a ~10M-param SmolLM-family reduction for 200 steps on the
deterministic synthetic LM task with 8 workers (2 Byzantine, random
gradients) — a few minutes on CPU.  ``--arch`` selects any assigned
architecture (reduced); ``--full-width`` uses d_model=768/12L (~100M) for
the production-shaped run.

    PYTHONPATH=src python examples/byzantine_train.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core.flag import FlagConfig
from repro.data.synthetic import SyntheticLM
from repro.data.pipeline import WorkerDataConfig, lm_worker_batches
from repro.dist.aggregation import AggregatorConfig
from repro.dist.train_step import TrainConfig, build_train_step, init_train_state
from repro.optim import adamw, warmup_cosine
from repro.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--byzantine", type=int, default=2)
    ap.add_argument("--attack", default="random")
    ap.add_argument("--aggregator", default="flag")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    if args.full_width:
        cfg = cfg.replace(d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, num_layers=12,
                          block_pattern=cfg.block_pattern * 6)
    cfg = cfg.replace(frontend=None, num_prefix_embeds=0)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"workers={args.workers} f={args.byzantine} attack={args.attack}")

    opt = adamw(weight_decay=0.01)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    lam = 0.0 if args.workers <= 6 else float(args.workers)
    tc = TrainConfig(
        aggregator=AggregatorConfig(
            name=args.aggregator, f=args.byzantine,
            flag=FlagConfig(lam=lam, regularizer="pairwise" if lam else "none")),
        attack=args.attack, attack_f=args.byzantine)
    step_fn = jax.jit(build_train_step(
        cfg, tc, opt, warmup_cosine(3e-3, args.steps, warmup=20)))

    task = SyntheticLM(vocab_size=cfg.vocab_size)
    wdc = WorkerDataConfig(workers=args.workers,
                           per_worker_batch=args.batch)
    t0 = time.time()
    for t in range(args.steps):
        batch = lm_worker_batches(task, wdc, t, args.seq)
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jax.random.PRNGKey(t),
                                       jnp.asarray(t, jnp.int32))
        if t % 20 == 0 or t == args.steps - 1:
            loss_v = float(m["loss"])
            gn = float(m["grad_global_norm"])
            print(f"step {t:4d} loss {loss_v:.4f} |g| {gn:.3f} "
                  f"({time.time()-t0:.0f}s)")
    if args.ckpt:
        path = save_checkpoint(args.ckpt, args.steps,
                               {"params": params, "opt": opt_state})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
