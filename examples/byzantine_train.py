"""End-to-end driver: train a language model with FA under Byzantine attack.

Defaults run a ~10M-param SmolLM-family reduction for 200 steps on the
deterministic synthetic LM task with 8 workers (2 Byzantine, random
gradients) — a few minutes on CPU.  ``--arch`` selects any assigned
architecture (reduced); ``--full-width`` uses d_model=768/12L (~100M) for
the production-shaped run.

``--codec`` turns on worker->server gradient compression (repro.comm):
signsgd / topk thread error-feedback memory through the loop, countsketch
feeds FA's Gram path with compressed payloads.  ``--lockstep`` gives every
worker the same batch (the concentration regime the robustness analysis
assumes — the config the compression acceptance tests train under).

    PYTHONPATH=src python examples/byzantine_train.py --steps 200
    PYTHONPATH=src python examples/byzantine_train.py --codec signsgd \\
        --lockstep --attack sign_flip --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.comm import CODECS, CommConfig, init_ef
from repro.configs import get_config, reduce_for_smoke
from repro.core.flag import FlagConfig
from repro.data.pipeline import WorkerDataConfig, lm_worker_batches
from repro.data.synthetic import SyntheticLM
from repro.dist.aggregation import AggregatorConfig
from repro.dist.train_step import TrainConfig, build_train_step, init_train_state
from repro.optim import adamw, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--byzantine", type=int, default=2)
    ap.add_argument("--attack", default="random")
    ap.add_argument("--aggregator", default="flag")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--codec", default="none", choices=("none",) + CODECS)
    ap.add_argument("--no-ef", action="store_true",
                    help="disable error feedback for biased codecs")
    ap.add_argument("--lockstep", action="store_true",
                    help="every worker sees the same batch")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    if args.full_width:
        cfg = cfg.replace(d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, num_layers=12,
                          block_pattern=cfg.block_pattern * 6)
    cfg = cfg.replace(frontend=None, num_prefix_embeds=0)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"workers={args.workers} f={args.byzantine} attack={args.attack}")

    opt = adamw(weight_decay=0.01)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    lam = 0.0 if args.workers <= 6 else float(args.workers)
    comm = CommConfig(codec=args.codec,
                      error_feedback=False if args.no_ef else None)
    tc = TrainConfig(
        aggregator=AggregatorConfig(
            name=args.aggregator, f=args.byzantine,
            flag=FlagConfig(lam=lam, regularizer="pairwise" if lam else "none")),
        attack=args.attack, attack_f=args.byzantine, comm=comm)
    step_fn = jax.jit(build_train_step(
        cfg, tc, opt, warmup_cosine(3e-3, args.steps, warmup=20)))
    ef = init_ef(params, args.workers) if comm.wants_ef else None

    task = SyntheticLM(vocab_size=cfg.vocab_size)
    wdc = WorkerDataConfig(workers=args.workers,
                           per_worker_batch=args.batch)
    t0 = time.time()
    m = None
    for t in range(args.steps):
        if args.lockstep:
            # same batch for every worker: honest gradients coincide, so
            # each attack is a pure displacement (concentration regime).
            one = task.batch(jax.random.fold_in(jax.random.PRNGKey(9), t),
                             args.batch, args.seq)
            batch = {k: jnp.broadcast_to(v[None], (args.workers,) + v.shape)
                     for k, v in one.items()}
        else:
            batch = lm_worker_batches(task, wdc, t, args.seq)
        if comm.wants_ef:
            params, opt_state, m, ef = step_fn(params, opt_state, batch,
                                               jax.random.PRNGKey(t),
                                               jnp.asarray(t, jnp.int32), ef)
        else:
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jax.random.PRNGKey(t),
                                           jnp.asarray(t, jnp.int32))
        if t % 20 == 0 or t == args.steps - 1:
            print(f"step {t:4d} loss {float(m['loss']):.4f} "
                  f"|g| {float(m['grad_global_norm']):.3f} "
                  f"comm {float(m['comm_ratio']):.1f}x "
                  f"({time.time()-t0:.0f}s)")
    if m is not None:
        print(f"final loss {float(m['loss']):.4f}  codec={args.codec} "
              f"comm_bits/step {float(m['comm_bits']):.3e} "
              f"({float(m['comm_ratio']):.1f}x saved)")
    if args.ckpt:
        path = save_checkpoint(args.ckpt, args.steps,
                               {"params": params, "opt": opt_state})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
