"""Quickstart: robust aggregation with the Flag Aggregator in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import FlagConfig, aggregators, flag_aggregate_gram

rng = np.random.default_rng(0)
n, p, f = 10_000, 15, 3

# honest workers: shared descent direction + minibatch noise
mu = rng.normal(size=n).astype(np.float32)
honest = mu[None] + 0.25 * rng.normal(size=(p - f, n)).astype(np.float32)
# Byzantine workers: large uniform-random gradients (the paper's Fig. 2/4
# threat model)
byz = rng.uniform(-20, 20, size=(f, n)).astype(np.float32)
G = jnp.asarray(np.concatenate([byz, honest]))          # (p, n) worker-major

target = honest.mean(axis=0)
for name in ("mean", "median", "multi_krum", "bulyan", "flag"):
    agg = aggregators.get_aggregator(name)
    kw = {"cfg": FlagConfig(lam=float(p))} if name == "flag" else {"f": f}
    d = agg(G, **kw)
    err = float(jnp.linalg.norm(d - target) / np.linalg.norm(target))
    print(f"{name:12s} relative error vs honest mean: {err:7.4f}")

# FA internals: per-worker combination weights + explained variance
d, aux = flag_aggregate_gram(G.T, FlagConfig(lam=float(p)))
print("\nFA combination weights (first 3 = Byzantine):")
print(np.round(np.asarray(aux["weights"]), 4))
print("explained variance per worker:")
print(np.round(np.asarray(aux["explained_variance"]), 3))
