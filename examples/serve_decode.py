"""Serving example: batched greedy decoding with a sharded KV cache.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
"""

import argparse

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.dist.serve_step import decode_loop
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch)).replace(
        frontend=None, num_prefix_embeds=0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    out = decode_loop(params, cfg, prompts, num_steps=args.gen,
                      max_len=args.prompt_len + args.gen + 1)
    print(f"arch={cfg.name} window={cfg.window} "
          f"pattern={cfg.block_pattern}")
    print("generated token ids:")
    for row in jax.device_get(out):
        print(" ", row.tolist())


if __name__ == "__main__":
    main()
