"""repro.analysis — jaxpr/HLO contract checking (graph lint).

The repo's strongest correctness guarantees are *graph-level*: the rank-p
solver materializes no dimension beyond p, no device ever holds the full
``(W, n)`` stack under a mesh, membership changes never recompile,
low-precision inputs accumulate in fp32.  This package is the one
enforced implementation of those invariants (docs/static_analysis.md):

* :mod:`repro.analysis.hlo` — the HLO-text substrate (shape scan,
  trip-count-corrected cost + collective parsing);
* :mod:`repro.analysis.rules` — the rule families (SHAPE, PRECISION,
  TRANSFER, MASK, COLLECTIVES) over captured :class:`Graph` objects;
* :mod:`repro.analysis.recompile` — the RECOMPILE runtime harness
  (``cache_size``, the generalized ``_cache_size() == 1``);
* :mod:`repro.analysis.contract` — the ``@contract`` entry-point
  decorator (zero-cost unless ``REPRO_CONTRACTS=1`` /
  :func:`enable_contracts`);
* :mod:`repro.analysis.entrypoints` — the public-entry-point sweep that
  ``tools/jaxlint.py`` and the CI ``lint-contracts`` lane run.
"""

from repro.analysis.contract import (checking, contract, contracts_enabled,
                                     enable_contracts)
from repro.analysis.findings import (ContractViolation, Finding, Report,
                                     format_findings)
from repro.analysis.hlo import (CollectiveStats, HloCost, parse_collectives,
                                parse_cost, shape_dims)
from repro.analysis.recompile import (assert_no_recompile, cache_size,
                                      check_recompile)
from repro.analysis.rules import (RULES, Graph, capture, check_collectives,
                                  check_mask, check_precision, check_shape,
                                  check_transfer, full_width_dims)

__all__ = [
    "CollectiveStats", "ContractViolation", "Finding", "Graph", "HloCost",
    "RULES", "Report", "assert_no_recompile", "cache_size", "capture",
    "check_collectives", "check_mask", "check_precision", "check_recompile",
    "check_shape", "check_transfer", "checking", "contract",
    "contracts_enabled", "enable_contracts", "format_findings",
    "full_width_dims", "parse_collectives", "parse_cost", "shape_dims",
]
