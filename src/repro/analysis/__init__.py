"""repro.analysis — jaxpr/HLO contract checking (graph lint).

The repo's strongest correctness guarantees are *graph-level*: the rank-p
solver materializes no dimension beyond p, no device ever holds the full
``(W, n)`` stack under a mesh, membership changes never recompile,
low-precision inputs accumulate in fp32.  This package is the one
enforced implementation of those invariants (docs/static_analysis.md):

* :mod:`repro.analysis.hlo` — the HLO-text substrate (shape scan,
  trip-count-corrected cost + collective parsing);
* :mod:`repro.analysis.rules` — the rule families (SHAPE, PRECISION,
  TRANSFER, MASK, COLLECTIVES) over captured :class:`Graph` objects;
* :mod:`repro.analysis.pallas_extract` / :mod:`repro.analysis.
  pallas_rules` — the kernel-level families (KTILING, KRACE, KVMEM,
  KPRECISION, KSENTINEL) that open every ``pallas_call`` box: grid /
  BlockSpec / index-map recovery plus kernel-body dataflow;
* :mod:`repro.analysis.recompile` — the RECOMPILE runtime harness
  (``cache_size``, the generalized ``_cache_size() == 1``);
* :mod:`repro.analysis.contract` — the ``@contract`` entry-point
  decorator (zero-cost unless ``REPRO_CONTRACTS=1`` /
  :func:`enable_contracts`);
* :mod:`repro.analysis.entrypoints` — the public-entry-point sweep that
  ``tools/jaxlint.py`` and the CI ``lint-contracts`` lane run.
"""

from repro.analysis.contract import (checking, contract, contracts_enabled,
                                     enable_contracts)
from repro.analysis.findings import (ContractViolation, Finding, Report,
                                     format_findings)
from repro.analysis.hlo import (CollectiveStats, HloCost, parse_collectives,
                                parse_cost, shape_dims)
from repro.analysis.pallas_extract import Block, PallasSite, find_pallas_calls
from repro.analysis.pallas_rules import (VMEM_BUDGET_BYTES,
                                         check_kernel_precision,
                                         check_kernel_race,
                                         check_kernel_sentinel,
                                         check_kernel_tiling,
                                         check_kernel_vmem, check_kernels)
from repro.analysis.recompile import (assert_no_recompile, cache_size,
                                      check_recompile)
from repro.analysis.rules import (RULES, Graph, capture, check_collectives,
                                  check_mask, check_precision, check_shape,
                                  check_transfer, full_width_dims)

__all__ = [
    "Block", "CollectiveStats", "ContractViolation", "Finding", "Graph",
    "HloCost", "PallasSite", "RULES", "Report", "VMEM_BUDGET_BYTES",
    "assert_no_recompile", "cache_size", "capture", "check_collectives",
    "check_kernel_precision", "check_kernel_race", "check_kernel_sentinel",
    "check_kernel_tiling", "check_kernel_vmem", "check_kernels",
    "check_mask", "check_precision", "check_recompile", "check_shape",
    "check_transfer", "checking", "contract", "contracts_enabled",
    "enable_contracts", "find_pallas_calls", "format_findings",
    "full_width_dims", "parse_collectives", "parse_cost", "shape_dims",
]
