"""The ``@contract`` decorator: declared graph invariants on entry points.

Usage (see ``repro.dist.aggregation`` / ``repro.core.gram`` for the live
sites)::

    @contract(fp32_contractions=True, no_full_width=True, mask_traced=True)
    def aggregate_tree(tree, cfg, *, gram=None, mask=None, sharded=None):
        ...

    @contract(max_dim=lambda K, *a, **k: K.shape[0])
    def fa_weights_from_gram(K, cfg, *, solver="rank_p", mask=None):
        ...

Semantics:

* **zero-cost when disabled** (the default): the wrapper is one global
  boolean check and a tail call.  Enable with ``REPRO_CONTRACTS=1`` in
  the environment, :func:`enable_contracts`, or the :func:`checking`
  context manager — the test suite and ``tools/jaxlint.py`` do.
* **checked at trace time, once per signature**: on the first call with
  a given (shapes/dtypes + static config) signature the entry point is
  traced to a jaxpr and the declared rules run; violations raise
  :class:`repro.analysis.findings.ContractViolation`.  Later calls with
  the same signature skip the (expensive) re-trace.
* **jit-transparent**: when any argument is a tracer the wrapper passes
  straight through — the enclosing jitted entry point is the one being
  checked, and nested contracted calls must not re-trace inside it.

Declared invariants:

* ``max_dim`` — SHAPE: no tensor dimension in the traced graph exceeds
  the bound; an int, or a callable of the call's ``(*args, **kwargs)``
  (e.g. ``lambda K, *a, **k: K.shape[0]`` for the rank-p solver).  A
  callable may return ``None`` to waive the bound for that call (the
  q-space oracle solver legitimately materializes q-sized buffers).
* ``no_full_width`` — SHAPE, active only when the call carries
  ``sharded=``: the entry point is re-lowered with the worker-major tree
  (the first positional argument) declared coordinate-sharded over the
  mesh, and no per-device tensor may carry a full coordinate width (each
  cleanly-divisible leaf's flat width, nor the concatenated total — see
  :func:`repro.analysis.rules.full_width_dims`).
* ``fp32_contractions`` — PRECISION over the traced jaxpr.
* ``no_host_transfers`` — TRANSFER over the traced jaxpr.
* ``mask_traced`` — MASK, active only when the call carries a non-None
  ``mask=``: the mask must be consumable as a traced operand and
  actually used.
* ``kernel_race`` — KTILING + KRACE over every ``pallas_call`` in the
  traced graph: tiles cover, stay in-bounds, and never overlap across
  grid steps; revisited output blocks follow the guarded-accumulation
  idiom.  Vacuously true when the graph lowers without Pallas (the CPU
  ``impl="pallas"`` fallback emits plain XLA) — the sweep entries trace
  the interpret-mode kernels explicitly so the rules always see real
  sites.
* ``kernel_budget`` — KVMEM: per-grid-step VMEM working set of every
  ``pallas_call`` vs this byte budget (``True`` for the default
  :data:`repro.analysis.pallas_rules.VMEM_BUDGET_BYTES`), plus
  lane/sublane block alignment.
"""

from __future__ import annotations

import functools
import os
from contextlib import contextmanager

import jax

from repro.analysis.findings import ContractViolation, Finding
from repro.analysis.rules import (Graph, capture, check_mask,
                                  check_precision, check_shape,
                                  check_transfer, full_width_dims)

__all__ = ["contract", "contracts_enabled", "enable_contracts", "checking"]


class _State:
    enabled = os.environ.get("REPRO_CONTRACTS", "").lower() in (
        "1", "true", "on", "yes")


def contracts_enabled() -> bool:
    return _State.enabled


def enable_contracts(on: bool = True) -> bool:
    """Turn contract checking on/off; returns the previous setting."""
    prev, _State.enabled = _State.enabled, bool(on)
    return prev


@contextmanager
def checking(on: bool = True):
    """Scoped :func:`enable_contracts` (the test-suite idiom)."""
    prev = enable_contracts(on)
    try:
        yield
    finally:
        enable_contracts(prev)


def _has_tracer(args, kwargs) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree.leaves((args, kwargs)))


def _sig_key(args, kwargs):
    def one(x):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            return ("arr", tuple(x.shape), str(x.dtype))
        try:
            return repr(x)
        except Exception:
            return type(x).__name__
    leaves, treedef = jax.tree.flatten(
        (args, kwargs),
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))
    return (str(treedef), tuple(one(leaf) for leaf in leaves))


def _check_full_width(fn, name, args, kwargs) -> list[Finding]:
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.dist.sharded import coord_axes, n_coord_shards

    # aggregate_tree-style entry points carry the mesh as ``sharded=``;
    # sharded_aggregate_tree carries it as ``mesh=``.
    sharded = kwargs.get("sharded")
    if sharded is None:
        sharded = kwargs.get("mesh")
    if not sharded:
        return []
    if isinstance(sharded, Mesh):
        mesh = sharded
    else:
        from repro.dist.sharding import current_mesh
        mesh = current_mesh()
        if mesh is None:
            return []
    tree = args[0]
    shards = n_coord_shards(mesh)
    forbidden, required = full_width_dims(tree, shards)
    if not forbidden:
        return []
    axes = coord_axes(mesh)

    def spec(leaf):
        sharding = [None] * leaf.ndim
        if leaf.ndim > 1 and leaf.shape[1] % shards == 0:
            sharding[1] = axes
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, P(*sharding)))

    tree_specs = jax.tree.map(spec, tree)
    hlo = jax.jit(
        lambda t: fn(t, *args[1:], **kwargs)).lower(
            tree_specs).compile().as_text()
    return check_shape(Graph(name, None, hlo), forbidden_dims=forbidden,
                       require_dims=required)


def contract(*, max_dim=None, no_full_width: bool = False,
             fp32_contractions: bool = False,
             no_host_transfers: bool = False, mask_traced: bool = False,
             kernel_race: bool = False, kernel_budget=None):
    """Declare graph invariants on an entry point (see module docstring)."""

    def deco(fn):
        name = getattr(fn, "__qualname__", getattr(fn, "__name__", "entry"))
        checked: set = set()

        def run_checks(args, kwargs):
            findings: list[Finding] = []
            if (max_dim is not None or fp32_contractions
                    or no_host_transfers or kernel_race
                    or kernel_budget is not None):
                graph = capture(fn, *args, name=name, compile=False,
                                **kwargs)
                if max_dim is not None:
                    bound = (max_dim(*args, **kwargs) if callable(max_dim)
                             else int(max_dim))
                    if bound is not None:  # callable may waive the bound
                        findings += check_shape(graph, max_dim=int(bound))
                if fp32_contractions:
                    findings += check_precision(graph)
                if no_host_transfers:
                    findings += check_transfer(graph)
                if kernel_race or kernel_budget is not None:
                    from repro.analysis.pallas_rules import (
                        VMEM_BUDGET_BYTES, check_kernel_race,
                        check_kernel_tiling, check_kernel_vmem, sites_of)

                    sites = sites_of(graph)
                    if kernel_race:
                        findings += check_kernel_tiling(sites, name=name)
                        findings += check_kernel_race(sites, name=name)
                    if kernel_budget is not None:
                        budget = (VMEM_BUDGET_BYTES
                                  if kernel_budget is True
                                  else float(kernel_budget))
                        findings += check_kernel_vmem(
                            sites, max_bytes=budget, name=name)
            if mask_traced and kwargs.get("mask") is not None:
                mask = kwargs["mask"]
                rest = {k: v for k, v in kwargs.items() if k != "mask"}
                findings += check_mask(
                    lambda m: fn(*args, mask=m, **rest), mask, name=name)
            if no_full_width:
                findings += _check_full_width(fn, name, args, kwargs)
            if findings:
                raise ContractViolation(findings, name=name)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _State.enabled or _has_tracer(args, kwargs):
                return fn(*args, **kwargs)
            key = _sig_key(args, kwargs)
            if key not in checked:
                run_checks(args, kwargs)
                checked.add(key)
            return fn(*args, **kwargs)

        wrapper.__contract__ = {
            "max_dim": max_dim, "no_full_width": no_full_width,
            "fp32_contractions": fp32_contractions,
            "no_host_transfers": no_host_transfers,
            "mask_traced": mask_traced, "kernel_race": kernel_race,
            "kernel_budget": kernel_budget}
        wrapper.__wrapped__ = fn
        return wrapper

    return deco
