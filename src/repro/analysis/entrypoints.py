"""The public-entry-point sweep: what ``tools/jaxlint.py`` checks.

One place defines which graphs get linted and against which contracts —
the CLI, the CI ``lint-contracts`` lane, and the tier-1 "entry points
are lint-clean" acceptance test (``tests/test_analysis.py``) all consume
:func:`run_sweep`.  Coverage:

* ``fa_weights_from_gram`` (rank-p solver) — SHAPE ``max_dim = p`` on
  the compiled HLO (PR 3's no-q-space invariant), PRECISION, TRANSFER.
* ``aggregate_tree`` for **all 11 rules** × {plain, masked, sketch} —
  PRECISION + TRANSFER on the traced jaxpr; MASK on the masked variant.
* ``compressed_aggregate`` (CountSketch gram-feed and signSGD+EF) —
  PRECISION + TRANSFER + MASK.
* serve path (prefill + one-token decode) on the reduced config at
  **bf16 compute** — PRECISION + TRANSFER (the production inference
  dtype; the fp32 smoke dtype would vacuously pass).
* train step (churn faults, FA aggregator) — PRECISION + TRANSFER.
* RECOMPILE harness — membership, the masked solver, and the serve step
  must hold ``cache_size == 1`` across value sweeps.
* sharded variants (needs >= 8 devices, e.g. under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — per-device
  SHAPE no-full-width + COLLECTIVES byte budget + PRECISION + TRANSFER
  on the compiled, partitioned HLO for all 11 rules.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis.findings import Report
from repro.analysis.recompile import check_recompile
from repro.analysis.rules import (Graph, capture, check_collectives,
                                  check_mask, check_precision, check_shape,
                                  check_transfer, full_width_dims)

__all__ = ["SWEEP_RULES", "sweep_entries", "run_sweep"]

W = 8          # worker count for the aggregation entries
SWEEP_RULES = ("mean", "flag", "pca", "median", "trimmed_mean", "meamed",
               "phocas", "krum", "multi_krum", "bulyan", "geomed")


@dataclass(frozen=True)
class Entry:
    name: str
    run: object                       # () -> list[Finding]


def _tree(seed: int = 0):
    """Clean power-of-two widths so the sharded variants divide an
    8-way mesh (1024 + 512 flat; total 1536)."""
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(W, 1024)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(W, 256, 2)),
                                   jnp.float32)}}


def _mask():
    return jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)


def _agg_cfg(name: str):
    from repro.core.flag import FlagConfig
    from repro.dist.aggregation import AggregatorConfig
    return AggregatorConfig(name=name, f=1,
                            flag=FlagConfig(lam=2.0, m=2, tol=0.0))


def _graph_rules(graph: Graph):
    return check_precision(graph) + check_transfer(graph)


# ---------------------------------------------------------------------------
# entry builders (lazy — nothing traces until Entry.run is called)
# ---------------------------------------------------------------------------

def _gram_solver_entry():
    def run():
        from repro.core.flag import FlagConfig
        from repro.core.gram import fa_weights_from_gram, gram_matrix
        p = 32
        rng = np.random.default_rng(23)
        K = gram_matrix(jnp.asarray(rng.normal(size=(4 * p, p)), jnp.float32))
        cfg = FlagConfig(lam=float(p))
        graph = capture(fa_weights_from_gram, K, cfg,
                        name="fa_weights_from_gram", compile=True)
        return (check_shape(graph, max_dim=p, require_dims={p})
                + _graph_rules(graph))
    return Entry("gram_solver/rank_p(p=32)", run)


def _aggregate_entries():
    from repro.dist.aggregation import GRAM_RULES, aggregate_tree
    entries = []
    for name in SWEEP_RULES:
        variants = ["plain", "masked"]
        if name in GRAM_RULES or name == "bulyan":
            variants.append("sketch")

        for variant in variants:
            def run(name=name, variant=variant):
                tree = _tree()
                cfg = _agg_cfg(name)
                if variant == "sketch":
                    import dataclasses
                    cfg = dataclasses.replace(cfg, sketch_stride=4)
                if variant == "masked":
                    findings = check_mask(
                        lambda m: aggregate_tree(tree, cfg, mask=m),
                        _mask(), name=f"aggregate_tree[{name}]")
                    graph = Graph(
                        f"aggregate_tree[{name}]",
                        jax.make_jaxpr(lambda m: aggregate_tree(
                            tree, cfg, mask=m))(_mask()))
                    return findings + _graph_rules(graph)
                graph = capture(aggregate_tree, tree, cfg,
                                name=f"aggregate_tree[{name}]",
                                compile=False)
                return _graph_rules(graph)

            entries.append(Entry(f"aggregate_tree/{name}/{variant}", run))
    return entries


def _compressed_entries():
    from repro.comm import CommConfig, init_ef
    from repro.dist.aggregation import compressed_aggregate

    def run_sketch():
        tree = _tree(1)
        comm = CommConfig(codec="countsketch", sketch_ratio=0.25)
        findings = check_mask(
            lambda m: compressed_aggregate(tree, _agg_cfg("flag"), comm,
                                           mask=m),
            _mask(), name="compressed_aggregate[countsketch]")
        graph = capture(compressed_aggregate, tree, _agg_cfg("flag"), comm,
                        name="compressed_aggregate[countsketch]",
                        compile=False)
        return findings + _graph_rules(graph)

    def run_ef():
        tree = _tree(2)
        comm = CommConfig(codec="signsgd")
        params = jax.tree.map(lambda l: l[0], tree)
        ef = init_ef(params, W)
        graph = capture(compressed_aggregate, tree, _agg_cfg("mean"), comm,
                        ef, name="compressed_aggregate[signsgd+ef]",
                        compile=False)
        return _graph_rules(graph)

    return [Entry("compressed_aggregate/countsketch/gram-feed", run_sketch),
            Entry("compressed_aggregate/signsgd/ef", run_ef)]


def _serve_entries():
    def _cfg_bf16():
        from repro.configs import get_config, reduce_for_smoke
        return reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0, compute_dtype="bfloat16")

    def run_prefill():
        from repro.dist.serve_step import build_prefill_step
        from repro.models import transformer
        cfg = _cfg_bf16()
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
        graph = capture(build_prefill_step(cfg), params, batch,
                        name="prefill_step[bf16]", compile=False)
        return _graph_rules(graph)

    def run_decode():
        from repro.dist.serve_step import build_serve_step
        from repro.models import transformer
        cfg = _cfg_bf16()
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        caches = transformer.init_caches(cfg, 2, 32, jnp.float32)
        graph = capture(build_serve_step(cfg, max_len=32), params, caches,
                        jnp.zeros((2, 1), jnp.int32),
                        jnp.zeros((), jnp.int32),
                        name="serve_step[bf16]", compile=False)
        return _graph_rules(graph)

    return [Entry("serve/prefill/bf16", run_prefill),
            Entry("serve/decode/bf16", run_decode)]


def _train_entry():
    def run():
        from repro.configs import get_config, reduce_for_smoke
        from repro.core.flag import FlagConfig
        from repro.dist.aggregation import AggregatorConfig
        from repro.dist.membership import get_fault_schedule
        from repro.dist.train_step import (TrainConfig, build_train_step,
                                           init_train_state)
        from repro.optim import constant, sgd
        cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0)
        Wt = 4
        tc = TrainConfig(
            aggregator=AggregatorConfig(
                name="flag", flag=FlagConfig(lam=0.0, regularizer="none")),
            faults=get_fault_schedule("churn", Wt, period=2, horizon=16))
        opt = sgd(momentum=0.9)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step = build_train_step(cfg, tc, opt, constant(1e-3))
        rng = np.random.default_rng(7)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (Wt, 2, 16)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (Wt, 2, 16)), jnp.int32)}
        graph = capture(step, params, opt_state, batch,
                        jax.random.PRNGKey(1), jnp.zeros((), jnp.int32),
                        name="train_step[flag+churn]", compile=False)
        return _graph_rules(graph)

    return Entry("train_step/flag/churn", run)


def _recompile_entries():
    def run_membership():
        from repro.dist.membership import get_fault_schedule, membership_at
        sched = get_fault_schedule("churn", 4, period=3, horizon=30)
        f = jax.jit(lambda t: membership_at(sched, t, 4))
        return check_recompile(
            f, [(jnp.asarray(t, jnp.int32),) for t in range(6)],
            name="membership_at")

    def run_masked_solver():
        from repro.core.flag import FlagConfig
        from repro.core.gram import fa_weights_from_gram, gram_matrix
        rng = np.random.default_rng(3)
        K = gram_matrix(jnp.asarray(rng.normal(size=(32, W)), jnp.float32))
        cfg = FlagConfig(lam=2.0, m=2, tol=0.0)
        f = jax.jit(lambda k, m: fa_weights_from_gram(k, cfg, mask=m))
        masks = [np.ones(W), np.r_[np.zeros(2), np.ones(W - 2)],
                 np.r_[np.ones(W - 3), np.zeros(3)]]
        return check_recompile(
            f, [(K, jnp.asarray(m, jnp.float32)) for m in masks],
            name="fa_weights_from_gram[masked]")

    def run_serve():
        from repro.configs import get_config, reduce_for_smoke
        from repro.dist.serve_step import build_serve_step
        from repro.models import transformer
        cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        caches = transformer.init_caches(cfg, 1, 16, jnp.float32)
        f = jax.jit(build_serve_step(cfg, max_len=16))
        tok = jnp.zeros((1, 1), jnp.int32)
        variants = []
        for t in range(3):
            variants.append((params, caches, tok, jnp.asarray(t, jnp.int32)))
        return check_recompile(f, variants, name="serve_step")

    return [Entry("recompile/membership_at", run_membership),
            Entry("recompile/fa_weights_masked", run_masked_solver),
            Entry("recompile/serve_step", run_serve)]


def _sharded_entries():
    entries = []
    for name in SWEEP_RULES:
        def run(name=name):
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.dist.aggregation import aggregate_tree
            from repro.dist.sharded import coord_axes, n_coord_shards
            from repro.launch.mesh import make_host_mesh
            tree = _tree()
            mesh = make_host_mesh(8)
            shards = n_coord_shards(mesh)
            axes = coord_axes(mesh)
            forbidden, required = full_width_dims(tree, shards)
            specs = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    l.shape, l.dtype,
                    sharding=NamedSharding(
                        mesh, P(None, axes, *([None] * (l.ndim - 2))))),
                tree)
            cfg = _agg_cfg(name)
            hlo = jax.jit(
                lambda t: aggregate_tree(t, cfg, sharded=mesh)).lower(
                    specs).compile().as_text()
            graph = Graph(f"aggregate_tree[{name},sharded]", None, hlo)
            n_flat = sum(
                math.prod(l.shape[1:]) for l in jax.tree.leaves(tree))
            # budget: the wire story is O(n + W^2) per device — one (W, W)
            # psum for the Gram plus at most one n-sized redistribution of
            # the combined update; a naive W*n gradient exchange busts it.
            budget = 4.0 * n_flat * 2 + 4.0 * W * W * 64
            return (check_shape(graph, forbidden_dims=forbidden,
                                require_dims=required)
                    + check_collectives(graph, shards,
                                        max_bytes_per_device=budget)
                    + check_precision(graph) + check_transfer(graph))

        entries.append(Entry(f"aggregate_tree/{name}/sharded", run))
    return entries


def sweep_entries(*, sharded: str = "auto") -> list[Entry]:
    """Every lintable entry point.

    ``sharded``: ``'auto'`` includes the mesh variants iff >= 8 devices
    are visible, ``'force'`` includes them unconditionally, ``'skip'``
    leaves them out (the single-device tier-1 path — CI runs them in the
    lint lane under a forced 8-device host platform).
    """
    entries = ([_gram_solver_entry()] + _aggregate_entries()
               + _compressed_entries() + _serve_entries() + [_train_entry()]
               + _recompile_entries())
    want_sharded = (sharded == "force"
                    or (sharded == "auto" and jax.device_count() >= 8))
    if want_sharded:
        entries += _sharded_entries()
    return entries


def run_sweep(*, sharded: str = "auto", names=None,
              progress=None) -> Report:
    """Run the sweep; returns a :class:`Report` (``.clean`` gates CI)."""
    report = Report()
    for entry in sweep_entries(sharded=sharded):
        if names and not any(s in entry.name for s in names):
            continue
        if progress:
            progress(entry.name)
        report.add(entry.name, entry.run())
    return report
