"""The public-entry-point sweep: what ``tools/jaxlint.py`` checks.

One place defines which graphs get linted and against which contracts —
the CLI, the CI ``lint-contracts`` lane, and the tier-1 "entry points
are lint-clean" acceptance test (``tests/test_analysis.py``) all consume
:func:`run_sweep`.  Coverage:

* ``fa_weights_from_gram`` (rank-p solver) — SHAPE ``max_dim = p`` on
  the compiled HLO (PR 3's no-q-space invariant), PRECISION, TRANSFER.
* ``aggregate_tree`` for **all 11 rules** × {plain, masked, sketch} —
  PRECISION + TRANSFER on the traced jaxpr; MASK on the masked variant.
* ``compressed_aggregate`` (CountSketch gram-feed and signSGD+EF) —
  PRECISION + TRANSFER + MASK.
* serve path (prefill + one-token decode) on the reduced config at
  **bf16 compute** — PRECISION + TRANSFER (the production inference
  dtype; the fp32 smoke dtype would vacuously pass).
* train step (churn faults, FA aggregator) — PRECISION + TRANSFER.
* RECOMPILE harness — membership, the masked solver, and the serve step
  must hold ``cache_size == 1`` across value sweeps.
* sharded variants (needs >= 8 devices, e.g. under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — per-device
  SHAPE no-full-width + COLLECTIVES byte budget + PRECISION + TRANSFER
  on the compiled, partitioned HLO for all 11 rules.
* **kernel entries** — every production ``pallas_call`` site (gram:
  per-matrix / fused-tree / sketch-stride; coord_stats: plain, the
  meamed key-value sort path, masked, Krum, Bulyan; flash_attn: bf16
  prefill + decode; weighted_sum; plus the full ``aggregate_tree``
  graph at ``impl='pallas_interpret'``, and its sharded twin in the
  mesh block) — linted with the five K-rule families (KTILING / KRACE /
  KVMEM / KPRECISION / KSENTINEL) via
  :func:`repro.analysis.pallas_rules.check_kernels`.  Each entry pins
  the expected site count so the sweep can never pass vacuously on a
  graph that lowered without Pallas.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Report
from repro.analysis.recompile import check_recompile
from repro.analysis.rules import (Graph, capture, check_collectives,
                                  check_mask, check_precision, check_shape,
                                  check_transfer, full_width_dims)

__all__ = ["SWEEP_RULES", "sweep_entries", "run_sweep"]

W = 8          # worker count for the aggregation entries
SWEEP_RULES = ("mean", "flag", "pca", "median", "trimmed_mean", "meamed",
               "phocas", "krum", "multi_krum", "bulyan", "geomed")


@dataclass(frozen=True)
class Entry:
    name: str
    run: object                       # () -> list[Finding]


def _tree(seed: int = 0):
    """Clean power-of-two widths so the sharded variants divide an
    8-way mesh (1024 + 512 flat; total 1536)."""
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(W, 1024)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(W, 256, 2)),
                                   jnp.float32)}}


def _mask():
    return jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)


def _agg_cfg(name: str):
    from repro.core.flag import FlagConfig
    from repro.dist.aggregation import AggregatorConfig
    return AggregatorConfig(name=name, f=1,
                            flag=FlagConfig(lam=2.0, m=2, tol=0.0))


def _graph_rules(graph: Graph):
    return check_precision(graph) + check_transfer(graph)


# ---------------------------------------------------------------------------
# entry builders (lazy — nothing traces until Entry.run is called)
# ---------------------------------------------------------------------------

def _gram_solver_entry():
    def run():
        from repro.core.flag import FlagConfig
        from repro.core.gram import fa_weights_from_gram, gram_matrix
        p = 32
        rng = np.random.default_rng(23)
        K = gram_matrix(jnp.asarray(rng.normal(size=(4 * p, p)), jnp.float32))
        cfg = FlagConfig(lam=float(p))
        graph = capture(fa_weights_from_gram, K, cfg,
                        name="fa_weights_from_gram", compile=True)
        return (check_shape(graph, max_dim=p, require_dims={p})
                + _graph_rules(graph))
    return Entry("gram_solver/rank_p(p=32)", run)


def _aggregate_entries():
    from repro.dist.aggregation import GRAM_RULES, aggregate_tree
    entries = []
    for name in SWEEP_RULES:
        variants = ["plain", "masked"]
        if name in GRAM_RULES or name == "bulyan":
            variants.append("sketch")

        for variant in variants:
            def run(name=name, variant=variant):
                tree = _tree()
                cfg = _agg_cfg(name)
                if variant == "sketch":
                    import dataclasses
                    cfg = dataclasses.replace(cfg, sketch_stride=4)
                if variant == "masked":
                    findings = check_mask(
                        lambda m: aggregate_tree(tree, cfg, mask=m),
                        _mask(), name=f"aggregate_tree[{name}]")
                    graph = Graph(
                        f"aggregate_tree[{name}]",
                        jax.make_jaxpr(lambda m: aggregate_tree(
                            tree, cfg, mask=m))(_mask()))
                    return findings + _graph_rules(graph)
                graph = capture(aggregate_tree, tree, cfg,
                                name=f"aggregate_tree[{name}]",
                                compile=False)
                return _graph_rules(graph)

            entries.append(Entry(f"aggregate_tree/{name}/{variant}", run))
    return entries


def _compressed_entries():
    from repro.comm import CommConfig, init_ef
    from repro.dist.aggregation import compressed_aggregate

    def run_sketch():
        tree = _tree(1)
        comm = CommConfig(codec="countsketch", sketch_ratio=0.25)
        findings = check_mask(
            lambda m: compressed_aggregate(tree, _agg_cfg("flag"), comm,
                                           mask=m),
            _mask(), name="compressed_aggregate[countsketch]")
        graph = capture(compressed_aggregate, tree, _agg_cfg("flag"), comm,
                        name="compressed_aggregate[countsketch]",
                        compile=False)
        return findings + _graph_rules(graph)

    def run_ef():
        tree = _tree(2)
        comm = CommConfig(codec="signsgd")
        params = jax.tree.map(lambda l: l[0], tree)
        ef = init_ef(params, W)
        graph = capture(compressed_aggregate, tree, _agg_cfg("mean"), comm,
                        ef, name="compressed_aggregate[signsgd+ef]",
                        compile=False)
        return _graph_rules(graph)

    return [Entry("compressed_aggregate/countsketch/gram-feed", run_sketch),
            Entry("compressed_aggregate/signsgd/ef", run_ef)]


def _serve_entries():
    def _cfg_bf16():
        from repro.configs import get_config, reduce_for_smoke
        return reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0, compute_dtype="bfloat16")

    def run_prefill():
        from repro.dist.serve_step import build_prefill_step
        from repro.models import transformer
        cfg = _cfg_bf16()
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
        graph = capture(build_prefill_step(cfg), params, batch,
                        name="prefill_step[bf16]", compile=False)
        return _graph_rules(graph)

    def run_decode():
        from repro.dist.serve_step import build_serve_step
        from repro.models import transformer
        cfg = _cfg_bf16()
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        caches = transformer.init_caches(cfg, 2, 32, jnp.float32)
        graph = capture(build_serve_step(cfg, max_len=32), params, caches,
                        jnp.zeros((2, 1), jnp.int32),
                        jnp.zeros((), jnp.int32),
                        name="serve_step[bf16]", compile=False)
        return _graph_rules(graph)

    return [Entry("serve/prefill/bf16", run_prefill),
            Entry("serve/decode/bf16", run_decode)]


def _train_entry():
    def run():
        from repro.configs import get_config, reduce_for_smoke
        from repro.core.flag import FlagConfig
        from repro.dist.aggregation import AggregatorConfig
        from repro.dist.membership import get_fault_schedule
        from repro.dist.train_step import (TrainConfig, build_train_step,
                                           init_train_state)
        from repro.optim import constant, sgd
        cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0)
        Wt = 4
        tc = TrainConfig(
            aggregator=AggregatorConfig(
                name="flag", flag=FlagConfig(lam=0.0, regularizer="none")),
            faults=get_fault_schedule("churn", Wt, period=2, horizon=16))
        opt = sgd(momentum=0.9)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step = build_train_step(cfg, tc, opt, constant(1e-3))
        rng = np.random.default_rng(7)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (Wt, 2, 16)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (Wt, 2, 16)), jnp.int32)}
        graph = capture(step, params, opt_state, batch,
                        jax.random.PRNGKey(1), jnp.zeros((), jnp.int32),
                        name="train_step[flag+churn]", compile=False)
        return _graph_rules(graph)

    return Entry("train_step/flag/churn", run)


def _recompile_entries():
    def run_membership():
        from repro.dist.membership import get_fault_schedule, membership_at
        sched = get_fault_schedule("churn", 4, period=3, horizon=30)
        f = jax.jit(lambda t: membership_at(sched, t, 4))
        return check_recompile(
            f, [(jnp.asarray(t, jnp.int32),) for t in range(6)],
            name="membership_at")

    def run_masked_solver():
        from repro.core.flag import FlagConfig
        from repro.core.gram import fa_weights_from_gram, gram_matrix
        rng = np.random.default_rng(3)
        K = gram_matrix(jnp.asarray(rng.normal(size=(32, W)), jnp.float32))
        cfg = FlagConfig(lam=2.0, m=2, tol=0.0)
        f = jax.jit(lambda k, m: fa_weights_from_gram(k, cfg, mask=m))
        masks = [np.ones(W), np.r_[np.zeros(2), np.ones(W - 2)],
                 np.r_[np.ones(W - 3), np.zeros(3)]]
        return check_recompile(
            f, [(K, jnp.asarray(m, jnp.float32)) for m in masks],
            name="fa_weights_from_gram[masked]")

    def run_serve():
        from repro.configs import get_config, reduce_for_smoke
        from repro.dist.serve_step import build_serve_step
        from repro.models import transformer
        cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        caches = transformer.init_caches(cfg, 1, 16, jnp.float32)
        f = jax.jit(build_serve_step(cfg, max_len=16))
        tok = jnp.zeros((1, 1), jnp.int32)
        variants = []
        for t in range(3):
            variants.append((params, caches, tok, jnp.asarray(t, jnp.int32)))
        return check_recompile(f, variants, name="serve_step")

    return [Entry("recompile/membership_at", run_membership),
            Entry("recompile/fa_weights_masked", run_masked_solver),
            Entry("recompile/serve_step", run_serve)]


def _kernel_entries():
    """The K-rule block: one entry per production kernel configuration.

    Kernels are traced with ``interpret=True`` (or
    ``impl='pallas_interpret'``) so the ``pallas_call`` primitive is
    present in the jaxpr on every backend — the CPU ``impl='pallas'``
    dispatch deliberately lowers to plain XLA, which would leave the
    K-rules nothing to look at.  ``n_sites`` pins the expected site
    count (detector sanity).
    """
    from repro.analysis.pallas_rules import check_kernels

    def _ck(fn, *args, n_sites: int, mask_inputs=None, name: str = ""):
        jaxpr = jax.make_jaxpr(fn)(*args)
        return check_kernels(jaxpr, name=name, expect_sites=n_sites,
                             mask_inputs=mask_inputs)

    def _gm(seed=0, n=4096, p=15, dtype=jnp.float32):
        rng = np.random.default_rng(seed)
        return jnp.asarray(rng.normal(size=(n, p)), dtype)

    def run_gram():
        from repro.kernels.gram.kernel import gram_pallas
        return _ck(lambda g: gram_pallas(g, block_n=1024, interpret=True),
                   _gm(), n_sites=1, name="gram_pallas")

    def run_tree_gram(stride=1):
        from repro.kernels.gram.kernel import tree_gram_pallas
        X = jnp.asarray(
            np.random.default_rng(1).normal(size=(W, 5000)), jnp.float32)
        return _ck(lambda x: tree_gram_pallas(
            x, sketch_stride=stride, block_n=1024, interpret=True),
            X, n_sites=1, name=f"tree_gram_pallas[stride={stride}]")

    def run_coord(op, masked=False):
        from repro.kernels.coord_stats.kernel import coord_stats_pallas
        Gw = jnp.asarray(
            np.random.default_rng(2).normal(size=(15, 5000)), jnp.float32)
        if masked:
            mask = jnp.asarray(np.r_[np.ones(12), np.zeros(3)], jnp.float32)
            return _ck(lambda g, m: coord_stats_pallas(
                g, m, op=op, f=3, interpret=True), Gw, mask,
                n_sites=1, mask_inputs=(1,),
                name=f"coord_stats[{op},masked]")
        return _ck(lambda g: coord_stats_pallas(
            g, op=op, f=3, interpret=True), Gw,
            n_sites=1, name=f"coord_stats[{op}]")

    def run_krum():
        from repro.kernels.coord_stats.kernel import krum_scores_pallas
        D2 = jnp.asarray(
            np.random.default_rng(3).normal(size=(15, 15))**2, jnp.float32)
        return _ck(lambda d: krum_scores_pallas(d, f=3, interpret=True),
                   D2, n_sites=1, name="krum_scores_pallas")

    def run_bulyan():
        from repro.kernels.coord_stats.kernel import bulyan_select_pallas
        D2 = jnp.asarray(
            np.random.default_rng(4).normal(size=(15, 15))**2, jnp.float32)
        return _ck(lambda d: bulyan_select_pallas(d, f=3, interpret=True),
                   D2, n_sites=1, name="bulyan_select_pallas")

    def run_flash(decode=False):
        from repro.kernels.flash_attn.kernel import flash_attn_pallas
        rng = np.random.default_rng(5)
        sq, sk = (1, 512) if decode else (256, 384)
        q = jnp.asarray(rng.normal(size=(2, 2, sq, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(2, 2, sk, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(2, 2, sk, 64)), jnp.bfloat16)
        return _ck(lambda q, k, v: flash_attn_pallas(
            q, k, v, causal=not decode, interpret=True), q, k, v,
            n_sites=1,
            name=f"flash_attn[{'decode' if decode else 'prefill'},bf16]")

    def run_wsum():
        from repro.kernels.weighted_sum.kernel import weighted_sum_pallas
        rng = np.random.default_rng(6)
        G = jnp.asarray(rng.normal(size=(5000, W)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(W,)), jnp.float32)
        return _ck(lambda g, cc: weighted_sum_pallas(g, cc, interpret=True),
                   G, c, n_sites=1, name="weighted_sum_pallas")

    def run_aggregate_interp():
        import dataclasses
        from repro.dist.aggregation import aggregate_tree
        tree = _tree(8)
        cfg = dataclasses.replace(_agg_cfg("flag"),
                                  impl="pallas_interpret")
        return _ck(lambda t: aggregate_tree(t, cfg), tree,
                   # fused tree Gram + one weighted combine per leaf
                   n_sites=3,
                   name="aggregate_tree[flag,pallas_interpret]")

    return [
        Entry("kernels/gram/plain", run_gram),
        Entry("kernels/gram/tree", lambda: run_tree_gram(1)),
        Entry("kernels/gram/tree_sketch", lambda: run_tree_gram(4)),
        Entry("kernels/coord_stats/median", lambda: run_coord("median")),
        Entry("kernels/coord_stats/meamed_kv", lambda: run_coord("meamed")),
        Entry("kernels/coord_stats/masked",
              lambda: run_coord("median", masked=True)),
        Entry("kernels/coord_stats/krum", run_krum),
        Entry("kernels/coord_stats/bulyan", run_bulyan),
        Entry("kernels/flash_attn/prefill_bf16", lambda: run_flash(False)),
        Entry("kernels/flash_attn/decode_bf16", lambda: run_flash(True)),
        Entry("kernels/weighted_sum/plain", run_wsum),
        Entry("kernels/aggregate/flag_interpret", run_aggregate_interp),
    ]


def _sharded_entries():
    entries = []
    for name in SWEEP_RULES:
        def run(name=name):
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.dist.aggregation import aggregate_tree
            from repro.dist.sharded import coord_axes, n_coord_shards
            from repro.launch.mesh import make_host_mesh
            tree = _tree()
            mesh = make_host_mesh(8)
            shards = n_coord_shards(mesh)
            axes = coord_axes(mesh)
            forbidden, required = full_width_dims(tree, shards)
            specs = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    l.shape, l.dtype,
                    sharding=NamedSharding(
                        mesh, P(None, axes, *([None] * (l.ndim - 2))))),
                tree)
            cfg = _agg_cfg(name)
            hlo = jax.jit(
                lambda t: aggregate_tree(t, cfg, sharded=mesh)).lower(
                    specs).compile().as_text()
            graph = Graph(f"aggregate_tree[{name},sharded]", None, hlo)
            n_flat = sum(
                math.prod(l.shape[1:]) for l in jax.tree.leaves(tree))
            # budget: the wire story is O(n + W^2) per device — one (W, W)
            # psum for the Gram plus at most one n-sized redistribution of
            # the combined update; a naive W*n gradient exchange busts it.
            budget = 4.0 * n_flat * 2 + 4.0 * W * W * 64
            return (check_shape(graph, forbidden_dims=forbidden,
                                require_dims=required)
                    + check_collectives(graph, shards,
                                        max_bytes_per_device=budget)
                    + check_precision(graph) + check_transfer(graph))

        entries.append(Entry(f"aggregate_tree/{name}/sharded", run))

    def run_sharded_kernels():
        import dataclasses
        from repro.analysis.pallas_rules import check_kernels
        from repro.dist.sharded import sharded_aggregate_tree
        from repro.launch.mesh import make_host_mesh
        tree = _tree(9)
        mesh = make_host_mesh(8)
        cfg = dataclasses.replace(_agg_cfg("flag"),
                                  impl="pallas_interpret")
        jaxpr = jax.make_jaxpr(
            lambda t: sharded_aggregate_tree(t, cfg, mesh=mesh))(tree)
        # shard-local fused Gram + one weighted combine per leaf, all
        # inside the shard_map body
        return check_kernels(jaxpr, expect_sites=3,
                             name="sharded_aggregate_tree[flag,interp]")

    entries.append(Entry("kernels/aggregate/sharded_interpret",
                         run_sharded_kernels))
    return entries


def sweep_entries(*, sharded: str = "auto") -> list[Entry]:
    """Every lintable entry point.

    ``sharded``: ``'auto'`` includes the mesh variants iff >= 8 devices
    are visible, ``'force'`` includes them unconditionally, ``'skip'``
    leaves them out (the single-device tier-1 path — CI runs them in the
    lint lane under a forced 8-device host platform).
    """
    entries = ([_gram_solver_entry()] + _aggregate_entries()
               + _compressed_entries() + _serve_entries() + [_train_entry()]
               + _recompile_entries() + _kernel_entries())
    want_sharded = (sharded == "force"
                    or (sharded == "auto" and jax.device_count() >= 8))
    if want_sharded:
        entries += _sharded_entries()
    return entries


def run_sweep(*, sharded: str = "auto", names=None,
              progress=None) -> Report:
    """Run the sweep; returns a :class:`Report` (``.clean`` gates CI)."""
    report = Report()
    for entry in sweep_entries(sharded=sharded):
        if names and not any(s in entry.name for s in names):
            continue
        if progress:
            progress(entry.name)
        report.add(entry.name, entry.run())
    return report
