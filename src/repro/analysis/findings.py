"""Structured findings — the common currency of every analysis rule.

A :class:`Finding` pins a violated graph invariant to a rule family, the
offending op, the computation it lives in, and the textual evidence (the
jaxpr equation or HLO line).  Rules return ``list[Finding]`` — empty means
clean — so callers compose them freely: the ``@contract`` decorator raises
:class:`ContractViolation` on any, ``tools/jaxlint.py`` prints and exits
nonzero, tests assert emptiness (or, for true-positive fixtures, assert a
specific rule id shows up).
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Finding:
    """One violated invariant, pinned to graph evidence.

    ``rule`` is the registry id (``shape`` | ``precision`` | ``recompile``
    | ``transfer`` | ``mask`` | ``collectives``); ``op`` the jaxpr
    primitive / HLO opcode (or a rule-specific tag); ``computation`` the
    jaxpr scope or HLO computation the op lives in; ``evidence`` the raw
    equation/line text (truncated for display); ``message`` the
    human-readable statement of what bound was broken and by what.
    """

    rule: str
    op: str
    computation: str
    evidence: str
    message: str

    def render(self, *, width: int = 100) -> str:
        ev = " ".join(self.evidence.split())
        if len(ev) > width:
            ev = ev[: width - 3] + "..."
        return (f"[{self.rule}] {self.message}\n"
                f"    op={self.op} computation={self.computation}\n"
                f"    evidence: {ev}")

    def to_dict(self) -> dict:
        """JSON-ready mapping (the ``jaxlint --json`` payload unit)."""
        return asdict(self)


def format_findings(findings: list[Finding], *, header: str = "") -> str:
    if not findings:
        return header + "clean (0 findings)" if header else "clean"
    lines = [header] if header else []
    lines += [f.render() for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)


class ContractViolation(AssertionError):
    """A graph contract was broken; carries the structured findings.

    Subclasses ``AssertionError`` so pytest renders it as a first-class
    assertion failure and ``pytest.raises(AssertionError)`` guards keep
    working in callers that don't know about the analysis layer.
    """

    def __init__(self, findings: list[Finding], *, name: str = ""):
        self.findings = list(findings)
        self.name = name
        head = f"contract violated: {name}" if name else "contract violated"
        super().__init__(format_findings(self.findings, header=head + "\n"))


@dataclass
class Report:
    """Accumulated findings over a sweep (one entry point per section)."""

    sections: list[tuple[str, list[Finding]]] = field(default_factory=list)

    def add(self, name: str, findings: list[Finding]) -> None:
        self.sections.append((name, list(findings)))

    @property
    def findings(self) -> list[Finding]:
        return [f for _, fs in self.sections for f in fs]

    @property
    def clean(self) -> bool:
        return not self.findings

    def render(self) -> str:
        lines = []
        for name, fs in self.sections:
            status = "ok" if not fs else f"{len(fs)} finding(s)"
            lines.append(f"{name:58s} {status}")
            lines += ["  " + ln for f in fs for ln in f.render().splitlines()]
        lines.append(f"-- {len(self.sections)} entry point(s), "
                     f"{len(self.findings)} finding(s) total")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        """Machine-readable sweep result (``jaxlint --json`` / the CI
        artifact): one object per entry point, findings as dicts."""
        return {
            "clean": self.clean,
            "total_findings": len(self.findings),
            "entries": [
                {"entry": name, "clean": not fs,
                 "findings": [f.to_dict() for f in fs]}
                for name, fs in self.sections
            ],
        }
