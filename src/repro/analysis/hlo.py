"""Parse collective ops out of compiled (SPMD-partitioned) HLO text.

``cost_analysis()`` does not report collective traffic — and it counts
``while`` bodies once — so the roofline's collective term comes from here:

1. the HLO text is split into computations;
2. every all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute op's *per-device* byte volume is derived from the
   op's output shape (post-partition HLO shapes are per-device) and its
   replica-group size with the standard ring multipliers:

       all-gather          out_bytes * (g-1)/g      (bytes received)
       all-reduce          out_bytes * 2(g-1)/g     (reduce-scatter + gather)
       reduce-scatter      out_bytes * (g-1)
       all-to-all          out_bytes * (g-1)/g
       collective-permute  out_bytes

3. ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}``
   after XLA's loop analysis; each computation's collectives are multiplied
   by the product of enclosing-loop trip counts (nested scans compose), so
   scanned-layer models report the same collective volume as unrolled ones
   (validated in tests/test_hlo_stats.py and against an unrolled dry-run).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")
WHILE_RE = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+)")
TRIP_RE = re.compile(r"known_trip_count\D+(\d+)")


def shape_dims(hlo_text: str) -> set[int]:
    """Every array dimension appearing in any typed shape of the HLO text.

    Used to assert *absence* of blow-up intermediates: e.g. the rank-p FA
    solver at p=32 must never materialize an array with a q-sized
    dimension (q = p + p(p-1)/2 = 528) — see tests/test_gram_solvers.py.
    """
    dims: set[int] = set()
    for dt, ds in SHAPE_RE.findall(hlo_text):
        if dt not in DTYPE_BYTES:
            continue
        for d in ds.split(","):
            if d:
                dims.add(int(d))
    return dims


def _shape_bytes(shape_text: str, last_only: bool = False) -> int:
    shapes = SHAPE_RE.findall(shape_text)
    if not shapes:
        return 0
    if last_only:
        shapes = shapes[-1:]
    total = 0
    for dt, dims in shapes:
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = GROUPS_EXPL_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return default


def _moved_bytes(kind: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    return {
        "all-gather": out_bytes * (g - 1) / g,
        "all-reduce": out_bytes * 2 * (g - 1) / g,
        "reduce-scatter": out_bytes * (g - 1),
        "all-to-all": out_bytes * (g - 1) / g,
        "collective-permute": float(out_bytes),
    }.get(kind, 0.0)


OP_LINE_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
    r"([\w\-]+)\(([^)]*)\)")
OPERAND_RE = re.compile(r"%([\w.\-]+)")
CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dims(shape_text: str) -> list[int]:
    m = SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class HloCost:
    flops: float            # loop-corrected dot FLOPs, per device
    hbm_bytes: float        # loop-corrected op-boundary bytes, per device
    raw_flops: float        # uncorrected (for comparison with cost_analysis)


def parse_cost(hlo_text: str) -> HloCost:
    """Loop-corrected FLOPs + HBM-traffic estimate from partitioned HLO.

    XLA's HloCostAnalysis counts while bodies once; this walks the
    computation graph with trip-count multipliers instead.  FLOPs counts
    ``dot`` ops (2 * prod(out) * prod(contracted lhs dims)) anywhere they
    appear; HBM bytes counts operand+output bytes of ops in *control*
    computations only (entry, while bodies, branches) — ops inside fusion
    computations don't touch HBM, the fusion call-site does.
    """
    comp = "<preamble>"
    shapes: dict[str, str] = {}
    comp_ops: dict = defaultdict(list)   # comp -> [(name, shape, op, opnds, line)]
    while_edges: list = []
    call_edges: list = []                # (parent, callee) for fusion/call
    fusion_comps: set = set()
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        hm = COMP_HEADER_RE.match(line)
        if hm:
            comp = hm.group(1)
            if raw.startswith("ENTRY") or line.startswith("ENTRY"):
                entry = comp
            continue
        om = OP_LINE_RE.match(line)
        if not om:
            continue
        name, shape_text, op, operands = om.groups()
        shapes[name] = shape_text
        comp_ops[comp].append((name, shape_text, op, operands, line))
        if op == "while":
            wm = WHILE_RE.search(line)
            tm = TRIP_RE.search(line)
            if wm:
                while_edges.append((comp, wm.group(1),
                                    int(tm.group(1)) if tm else 1))
        cm = CALLS_RE.search(line)
        if cm and op in ("fusion", "call", "custom-call", "reduce", "map",
                         "sort", "scatter", "select-and-scatter"):
            call_edges.append((comp, cm.group(1)))
            if op == "fusion":
                fusion_comps.add(cm.group(1))

    mult: dict = defaultdict(lambda: 0.0)
    mult[entry or "<preamble>"] = 1.0
    for _ in range(32):
        changed = False
        for parent, body, trips in while_edges:
            new = mult[parent] * trips
            if new > mult.get(body, 0.0):
                mult[body] = new
                changed = True
        for parent, callee in call_edges:
            new = mult[parent]
            if new > mult.get(callee, 0.0):
                mult[callee] = new
                changed = True
        if not changed:
            break
    # computations that were never reached (e.g. cond computations) get 1x
    flops = raw_flops = hbm = 0.0
    for comp_name, ops in comp_ops.items():
        m = mult.get(comp_name, 1.0) or 1.0
        in_fusion = comp_name in fusion_comps
        for name, shape_text, op, operands, line in ops:
            if op == "dot":
                out_n = 1
                for d in _dims(shape_text):
                    out_n *= d
                contract = 1
                cm2 = CONTRACT_RE.search(line)
                opnd_names = OPERAND_RE.findall(operands)
                if cm2 and opnd_names:
                    lhs_dims = _dims(shapes.get(opnd_names[0], ""))
                    for idx in cm2.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                f = 2.0 * out_n * contract
                flops += f * m
                raw_flops += f
            if not in_fusion and op not in ("parameter", "constant",
                                            "get-tuple-element", "tuple",
                                            "bitcast"):
                b = _shape_bytes(shape_text)
                for opn in OPERAND_RE.findall(operands):
                    if opn in shapes:
                        b += _shape_bytes(shapes[opn])
                hbm += b * m
    return HloCost(flops=flops, hbm_bytes=hbm, raw_flops=raw_flops)


@dataclass
class CollectiveStats:
    per_kind_bytes: dict
    per_kind_count: dict
    total_moved_bytes: float                    # per device, loop-corrected
    loop_multipliers: dict = field(default_factory=dict)

    def summary(self) -> str:
        parts = [f"{k}: n={self.per_kind_count[k]} "
                 f"moved={self.per_kind_bytes[k]/1e6:.1f}MB"
                 for k in sorted(self.per_kind_bytes)]
        return "; ".join(parts) or "none"


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    # --- pass 1: split into computations; record whiles + trip counts ---
    comp = "<preamble>"
    per_comp_ops: dict = defaultdict(list)      # comp -> [(kind, moved, n)]
    while_edges: list = []                      # (parent_comp, body, trips)
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        hm = COMP_HEADER_RE.match(line)
        if hm:
            comp = hm.group(1)
            if raw.startswith("ENTRY") or line.startswith("ENTRY"):
                entry = comp
            continue
        wm = WHILE_RE.search(line)
        if wm:
            tm = TRIP_RE.search(line)
            trips = int(tm.group(1)) if tm else 1
            while_edges.append((comp, wm.group(1), trips))
        cm = COLLECTIVE_RE.search(line)
        if cm:
            shape_text, op = cm.group(1), cm.group(2)
            if op.endswith("-start"):
                op = op[:-6]
            # async -start ops have tuple (operand, result) shapes: use result
            last_only = shape_text.startswith("(")
            out_bytes = _shape_bytes(shape_text, last_only=last_only)
            g = _group_size(line, total_devices)
            per_comp_ops[comp].append((op, _moved_bytes(op, out_bytes, g)))

    # --- pass 2: propagate loop multipliers through the while-call graph ---
    mult: dict = defaultdict(lambda: 1.0)
    if entry:
        mult[entry] = 1.0
    # iterate to fixpoint (nesting depth is tiny)
    for _ in range(16):
        changed = False
        for parent, body, trips in while_edges:
            new = mult[parent] * trips
            if mult.get(body) != new:
                mult[body] = new
                changed = True
        if not changed:
            break

    per_bytes: dict = defaultdict(float)
    per_count: dict = defaultdict(int)
    for comp_name, ops in per_comp_ops.items():
        m = mult.get(comp_name, 1.0)
        for op, moved in ops:
            per_bytes[op] += moved * m
            per_count[op] += int(m) if m > 1 else 1
    return CollectiveStats(dict(per_bytes), dict(per_count),
                           sum(per_bytes.values()),
                           {b: t for _, b, t in while_edges})
