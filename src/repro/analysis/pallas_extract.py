"""Recover the structure of every ``pallas_call`` inside a traced jaxpr.

The jaxpr/HLO rules of :mod:`repro.analysis.rules` treat a
``pallas_call`` as an opaque primitive: its grid, BlockSpecs, index maps
and kernel body never cross the equation boundary, so none of the
invariants the kernel docstrings promise (guarded accumulation, inert
padding, finite sentinels) were enforced by anything.  This module is
the substrate that opens the box:

* :func:`find_pallas_calls` walks a jaxpr (through pjit / cond / scan /
  shard_map bodies) and returns one :class:`PallasSite` per call with
  the grid, per-operand :class:`Block` descriptors (block shape, padded
  operand shape, dtype, index-map jaxpr) and the raw kernel body jaxpr.
* :meth:`PallasSite.visits` **concretely evaluates** every index map
  over the full grid product — grids here are small and static (the
  chunk schedules of the production kernels), so exhaustive evaluation
  is exact where symbolic reasoning would have to approximate.  From the
  visit map, :meth:`PallasSite.dependent_axes` recovers which grid axes
  an operand's block index actually depends on; the complement (axes the
  map ignores, with extent > 1) are the *revisit* axes — the grid steps
  that hit the same output block again, i.e. exactly the steps a
  race/accumulation rule must reason about.

The rule families themselves (KTILING / KRACE / KVMEM / KPRECISION /
KSENTINEL) live in :mod:`repro.analysis.pallas_rules`.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import cached_property

import jax
import jax.numpy as jnp
import numpy as np
from jax import core as jax_core

__all__ = ["Block", "PallasSite", "find_pallas_calls", "grid_points",
           "MAX_GRID_POINTS"]

# Exhaustive index-map evaluation is exact but linear in the grid
# product; production grids are O(n / block_n) ~ hundreds of steps.  A
# grid beyond this bound is almost certainly a shape bug upstream — the
# analyzer refuses rather than silently sampling.
MAX_GRID_POINTS = 1 << 16


def grid_points(grid: tuple[int, ...]):
    """Iterate the full grid product in row-major order."""
    return itertools.product(*(range(g) for g in grid))


def _int_block_shape(block_shape) -> tuple[int, ...]:
    """BlockSpec dims as plain ints (mapped/squeezed dims count as 1)."""
    return tuple(d if isinstance(d, int) else 1 for d in block_shape)


@dataclass(frozen=True)
class Block:
    """One operand of a ``pallas_call``: its tiling and index map.

    ``array_shape`` is the shape of the operand the caller actually
    passed (the *padded* array — wrappers pad before dispatch), so
    in-bounds reasoning over ``block_shape`` x index map is exact.
    """

    role: str                               # "in" | "out"
    position: int                           # operand index within role
    block_shape: tuple[int, ...]
    array_shape: tuple[int, ...]
    dtype: jnp.dtype
    index_map: jax_core.ClosedJaxpr

    @property
    def block_bytes(self) -> int:
        size = 1
        for d in self.block_shape:
            size *= d
        return size * jnp.dtype(self.dtype).itemsize

    def grid_blocks(self) -> tuple[int, ...]:
        """Number of blocks covering the array along each dim (ceil)."""
        return tuple(-(-a // b) for a, b in
                     zip(self.array_shape, self.block_shape))


def _eval_structural(closed: jax_core.ClosedJaxpr):
    """Fast path for equation-free index maps (``lambda i, j: (j, 0)``).

    The outvars of an eqn-free jaxpr are a mix of invars and literals —
    the common case for every production kernel — so each grid point
    evaluates in pure Python with no dispatch.
    Returns None when the map actually computes something.
    """
    jaxpr = closed.jaxpr
    if jaxpr.eqns:
        return None
    positions = {v: i for i, v in enumerate(jaxpr.invars)}
    slots = []
    for ov in jaxpr.outvars:
        if isinstance(ov, jax_core.Literal):
            slots.append(("lit", int(ov.val)))
        elif ov in positions:
            slots.append(("arg", positions[ov]))
        else:
            return None                      # a constvar: fall back

    def run(idx):
        return tuple(v if tag == "lit" else idx[v] for tag, v in slots)
    return run


def _eval_vectorized(closed: jax_core.ClosedJaxpr, grid):
    """Evaluate a computing index map over the whole grid in one jitted
    vmap (one compile total, vs one eval_jaxpr dispatch chain per point)."""
    pts = np.asarray(list(grid_points(grid)), dtype=np.int32)
    if pts.size == 0:
        return {}

    def one(row):
        outs = jax_core.eval_jaxpr(closed.jaxpr, closed.consts,
                                   *[row[i] for i in range(pts.shape[1])])
        return tuple(jnp.asarray(o, jnp.int32) for o in outs)

    cols = jax.jit(jax.vmap(one))(jnp.asarray(pts))
    cols = [np.asarray(c) for c in cols]
    return {tuple(int(x) for x in pts[r]):
            tuple(int(c[r]) for c in cols)
            for r in range(pts.shape[0])}


@dataclass
class PallasSite:
    """One discovered ``pallas_call``, ready for the kernel rules."""

    name: str                               # kernel function name
    scope: str                              # jaxpr path to the call
    grid: tuple[int, ...]
    inputs: tuple[Block, ...]
    outputs: tuple[Block, ...]
    scratch_shapes: tuple[tuple[tuple[int, ...], jnp.dtype], ...]
    kernel: jax_core.Jaxpr                  # kernel body (refs as invars)
    num_index_operands: int
    input_output_aliases: tuple[tuple[int, int], ...]
    interpret: bool = False
    _visit_cache: dict = field(default_factory=dict, repr=False)

    @property
    def blocks(self) -> tuple[Block, ...]:
        return self.inputs + self.outputs

    @cached_property
    def n_grid_points(self) -> int:
        n = 1
        for g in self.grid:
            n *= g
        return n

    def kernel_refs(self, role: str) -> list:
        """Kernel-jaxpr invars holding the refs of ``role``
        (``in`` | ``out`` | ``scratch``), in operand order."""
        iv = list(self.kernel.invars)
        n_idx = self.num_index_operands
        n_in, n_out = len(self.inputs), len(self.outputs)
        if role == "in":
            return iv[n_idx:n_idx + n_in]
        if role == "out":
            return iv[n_idx + n_in:n_idx + n_in + n_out]
        if role == "scratch":
            return iv[n_idx + n_in + n_out:]
        raise ValueError(role)

    def visits(self, block: Block) -> dict[tuple[int, ...],
                                           list[tuple[int, ...]]]:
        """block index -> ordered list of grid points that map to it.

        Exact: every grid point of the (static) grid is evaluated
        through the operand's index map.
        """
        key = (block.role, block.position)
        if key in self._visit_cache:
            return self._visit_cache[key]
        if self.n_grid_points > MAX_GRID_POINTS:
            raise ValueError(
                f"pallas_call {self.name!r}: grid {self.grid} has "
                f"{self.n_grid_points} points > MAX_GRID_POINTS "
                f"({MAX_GRID_POINTS}); exhaustive index-map evaluation "
                "refused — shrink the analysis shapes")
        fast = _eval_structural(block.index_map)
        if fast is not None:
            mapping = {idx: fast(idx) for idx in grid_points(self.grid)}
        else:
            mapping = _eval_vectorized(block.index_map, self.grid)
        out: dict[tuple[int, ...], list[tuple[int, ...]]] = {}
        for gidx in grid_points(self.grid):
            out.setdefault(mapping[gidx], []).append(gidx)
        self._visit_cache[key] = out
        return out

    def dependent_axes(self, block: Block) -> set[int]:
        """Grid axes the block index actually depends on.

        Axis ``a`` is dependent iff two grid points differing *only* in
        ``a`` map to different block indices.  Because the full product
        is evaluated, a map constant along every single-axis line within
        a fiber is constant on the whole fiber — so grid points sharing
        a projection onto the dependent axes provably share a block.
        """
        visits = self.visits(block)
        point_to_block = {g: b for b, pts in visits.items() for g in pts}
        dependent: set[int] = set()
        for axis in range(len(self.grid)):
            if self.grid[axis] <= 1:
                continue
            seen: dict[tuple, tuple] = {}
            for gidx, bidx in point_to_block.items():
                proj = gidx[:axis] + gidx[axis + 1:]
                if proj in seen:
                    if seen[proj] != bidx:
                        dependent.add(axis)
                        break
                else:
                    seen[proj] = bidx
        return dependent

    def revisit_axes(self, block: Block) -> set[int]:
        """Grid axes (extent > 1) along which the same block is hit
        again — the axes an accumulation/race rule must reason about."""
        dep = self.dependent_axes(block)
        return {a for a in range(len(self.grid))
                if self.grid[a] > 1 and a not in dep}


def _kernel_fn_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    name = getattr(info, "name", None)
    return name or "pallas_call"


def _site_from_eqn(eqn, scope: str) -> PallasSite:
    gm = eqn.params["grid_mapping"]
    kernel = eqn.params["jaxpr"]
    if isinstance(kernel, jax_core.ClosedJaxpr):
        kernel = kernel.jaxpr
    mappings = list(gm.block_mappings)
    blocks: list[Block] = []
    for i, bm in enumerate(mappings):
        role = "in" if i < gm.num_inputs else "out"
        pos = i if role == "in" else i - gm.num_inputs
        sds = bm.array_shape_dtype
        blocks.append(Block(
            role=role, position=pos,
            block_shape=_int_block_shape(bm.block_shape),
            array_shape=tuple(int(d) for d in sds.shape),
            dtype=jnp.dtype(sds.dtype),
            index_map=bm.index_map_jaxpr))
    n_ref = gm.num_index_operands + gm.num_inputs + gm.num_outputs
    scratch = []
    for v in kernel.invars[n_ref:]:
        aval = getattr(v.aval, "inner_aval", v.aval)
        scratch.append((tuple(int(d) for d in getattr(aval, "shape", ())),
                        jnp.dtype(getattr(aval, "dtype", jnp.float32))))
    aliases = tuple(sorted(dict(eqn.params.get(
        "input_output_aliases", ())).items()))
    return PallasSite(
        name=_kernel_fn_name(eqn), scope=scope,
        grid=tuple(int(g) for g in gm.grid),
        inputs=tuple(b for b in blocks if b.role == "in"),
        outputs=tuple(b for b in blocks if b.role == "out"),
        scratch_shapes=tuple(scratch), kernel=kernel,
        num_index_operands=int(gm.num_index_operands),
        input_output_aliases=aliases,
        interpret=bool(eqn.params.get("interpret", False)))


def find_pallas_calls(jaxpr) -> list[PallasSite]:
    """Every ``pallas_call`` reachable from ``jaxpr`` (a ``Jaxpr`` or
    ``ClosedJaxpr``), in traversal order, through pjit / control-flow /
    shard_map sub-jaxprs."""
    from repro.analysis.rules import iter_eqns

    if isinstance(jaxpr, jax_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    sites = []
    for eqn, scope in iter_eqns(jaxpr):
        if eqn.primitive.name == "pallas_call":
            sites.append(_site_from_eqn(eqn, scope))
    return sites
