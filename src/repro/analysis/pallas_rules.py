"""Kernel-level rule families over extracted ``pallas_call`` sites.

PR 7's linter stops at the jaxpr/HLO graph level; these rules descend
into the kernels themselves via :mod:`repro.analysis.pallas_extract`.
Families (``K`` prefix = kernel-level; catalog in
docs/static_analysis.md):

``ktiling``
    Every output block is covered by the grid, every visited block is
    in-bounds for the *padded* operand, and each output block is written
    by exactly one grid index along the axes its index map depends on —
    overlap along a dependent (non-revisit) axis means two unrelated
    grid steps race on the same tile.
``krace``
    An output block revisited across grid steps must follow the
    guarded-accumulation idiom (flash_attn's k axis, the tree Gram's
    chunk axis): a write predicated on the first visiting step
    initializes the tile, and every unconditional write must derive
    from a prior read of the same ref (accumulate, never clobber).
    Writing an input ref without a declared ``input_output_alias`` —
    or declaring one whose index maps disagree — is also a race.
``kvmem``
    The per-grid-step VMEM working set (double-buffered block bytes +
    scratch) must fit a configurable budget, and block shapes must be
    lane/sublane aligned (or span the full array dim) for their dtype.
``kprecision``
    PR 7's PRECISION rule applied *inside* kernel bodies — bf16/fp16
    MXU contractions must carry ``preferred_element_type=f32`` — plus a
    kernel-only obligation: a revisited-and-read output ref is a
    cross-step accumulator and must be fp32.
``ksentinel``
    Masked kernels must use *finite* sentinels (``-1e30`` /
    ``finfo.max``, never ``+-inf``: inf-inf arithmetic inside the
    revisit loop manufactures NaNs that a mask can no longer remove),
    and must consume the membership mask as a traced ref operand.
"""

from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np
from jax import core as jax_core

from repro.analysis.findings import Finding
from repro.analysis.pallas_extract import (Block, PallasSite,
                                           find_pallas_calls)

__all__ = ["check_kernel_tiling", "check_kernel_race", "check_kernel_vmem",
           "check_kernel_precision", "check_kernel_sentinel",
           "check_kernels", "sites_of", "VMEM_BUDGET_BYTES", "K_RULES"]

# Per-core VMEM on current TPUs is ~16 MiB; the default budget leaves
# headroom for Mosaic's own spills.
VMEM_BUDGET_BYTES = 12 * 2 ** 20

_LOW = (jnp.bfloat16, jnp.float16)


def _is_low(dtype) -> bool:
    return any(jnp.dtype(dtype) == jnp.dtype(d) for d in _LOW)


def sites_of(graph_or_jaxpr) -> list[PallasSite]:
    """Accept a :class:`repro.analysis.rules.Graph`, a jaxpr, or a
    pre-extracted site list."""
    if isinstance(graph_or_jaxpr, list):
        return graph_or_jaxpr
    jaxpr = getattr(graph_or_jaxpr, "jaxpr", graph_or_jaxpr)
    if jaxpr is None:
        raise ValueError("kernel rules need a traced jaxpr (HLO has "
                         "already erased the pallas_call structure)")
    return find_pallas_calls(jaxpr)


def _blk(site: PallasSite, block: Block) -> str:
    return f"{site.name}/{block.role}[{block.position}]"


# ---------------------------------------------------------------------------
# KTILING
# ---------------------------------------------------------------------------

def check_kernel_tiling(graph_or_sites, *, name: str = "") -> list[Finding]:
    """KTILING: coverage, bounds, and single-writer tiling soundness."""
    findings: list[Finding] = []
    for site in sites_of(graph_or_sites):
        for block in site.blocks:
            visits = site.visits(block)
            for bidx in visits:
                oob = [k for k, (b, bs, a) in enumerate(
                    zip(bidx, block.block_shape, block.array_shape))
                    if b < 0 or (b + 1) * bs > a]
                if oob:
                    g0 = visits[bidx][0]
                    findings.append(Finding(
                        "ktiling", "oob-block", site.scope,
                        f"{_blk(site, block)} block {bidx} @ grid {g0}",
                        f"{_blk(site, block)}: block index {bidx} x block "
                        f"shape {block.block_shape} overruns the padded "
                        f"operand {block.array_shape} along dim(s) {oob} — "
                        "the kernel reads/writes out of bounds"))
            if block.role != "out":
                continue
            nblocks = block.grid_blocks()
            want = set(itertools.product(*(range(n) for n in nblocks)))
            missing = sorted(want - set(visits))
            if missing:
                findings.append(Finding(
                    "ktiling", "uncovered-block", site.scope,
                    f"{_blk(site, block)} missing {missing[:4]}"
                    f"{'...' if len(missing) > 4 else ''}",
                    f"{_blk(site, block)}: {len(missing)} of "
                    f"{len(want)} output block(s) are never written by "
                    "any grid step — the result carries uninitialized "
                    "memory"))
            dep = sorted(site.dependent_axes(block))
            for bidx, pts in visits.items():
                projs = {tuple(g[a] for a in dep) for g in pts}
                if len(projs) > 1:
                    findings.append(Finding(
                        "ktiling", "overlapping-tiles", site.scope,
                        f"{_blk(site, block)} block {bidx} <- grid "
                        f"projections {sorted(projs)[:4]}",
                        f"{_blk(site, block)}: output block {bidx} is "
                        f"written by {len(projs)} distinct grid indices "
                        f"along non-revisit axes {dep} — overlapping "
                        "tiles race on the same output"))
                    break                    # one finding per block map
    return findings


# ---------------------------------------------------------------------------
# kernel-body dataflow (shared by KRACE / KPRECISION / KSENTINEL)
# ---------------------------------------------------------------------------

_EMPTY = (frozenset(), frozenset())


def _union(*taints):
    axes: frozenset = frozenset()
    reads: frozenset = frozenset()
    for a, r in taints:
        axes |= a
        reads |= r
    return (axes, reads)


@dataclass
class _Access:
    ref: object                             # root kernel invar Var
    kind: str                               # "read" | "write" | "accum"
    conditional: bool
    guard_axes: frozenset                   # pid axes tainting the guard
    value_reads: frozenset                  # refs whose reads feed the value
    scope: str


def _walk_kernel(jaxpr, env, refmap, guard, scope, accesses):
    """Forward dataflow over a kernel (sub-)jaxpr.

    ``env`` maps vars to (pid-axes, refs-read) taints; ``refmap`` maps
    ref-typed vars to their root kernel invar; ``guard`` is the taint of
    the enclosing cond predicates (None at top level).  Returns the
    taints of the jaxpr's outvars.
    """
    def taint(v):
        if isinstance(v, jax_core.Literal):
            return _EMPTY
        return env.get(v, _EMPTY)

    def set_out(eqn, t):
        for ov in eqn.outvars:
            env[ov] = t

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        in_taints = [taint(v) for v in eqn.invars]
        if prim == "program_id":
            set_out(eqn, (frozenset({int(eqn.params["axis"])}),
                          frozenset()))
        elif prim == "get":
            ref = refmap.get(eqn.invars[0])
            if ref is not None:
                accesses.append(_Access(
                    ref, "read", guard is not None,
                    guard[0] if guard else frozenset(),
                    frozenset(), scope))
                set_out(eqn, _union(*in_taints,
                                    (frozenset(), frozenset({ref}))))
            else:
                set_out(eqn, _union(*in_taints))
        elif prim in ("swap", "addupdate"):
            ref = refmap.get(eqn.invars[0])
            val_taint = _union(*in_taints[1:])
            if ref is not None:
                accesses.append(_Access(
                    ref, "accum" if prim == "addupdate" else "write",
                    guard is not None,
                    guard[0] if guard else frozenset(),
                    val_taint[1], scope))
            set_out(eqn, (val_taint[0],
                          val_taint[1] | ({ref} if ref else set())))
        elif prim == "cond":
            pred_taint = in_taints[0]
            branch_guard = _union(pred_taint, guard or _EMPTY)
            outs = []
            for br in eqn.params["branches"]:
                sub = br.jaxpr if isinstance(br, jax_core.ClosedJaxpr) \
                    else br
                for sv, ov, t in zip(sub.invars, eqn.invars[1:],
                                     in_taints[1:]):
                    env[sv] = t
                    if not isinstance(ov, jax_core.Literal) \
                            and ov in refmap:
                        refmap[sv] = refmap[ov]
                outs.append(_walk_kernel(sub, env, refmap, branch_guard,
                                         scope + "/cond", accesses))
            merged = [_union(pred_taint, *[o[i] for o in outs])
                      for i in range(len(eqn.outvars))] or []
            for ov, t in zip(eqn.outvars, merged):
                env[ov] = t
        else:
            subs = [(k, v) for k, v in eqn.params.items()
                    if isinstance(v, (jax_core.Jaxpr,
                                      jax_core.ClosedJaxpr))]
            if not subs:
                set_out(eqn, _union(*in_taints))
                continue
            out_taint = _union(*in_taints)
            for key, sub in subs:
                sj = sub.jaxpr if isinstance(sub, jax_core.ClosedJaxpr) \
                    else sub
                # positional mapping: the trailing eqn invars line up
                # with the body invars (pjit/closed_call/scan exactly;
                # while bodies shifted by the cond consts — good enough
                # for ref identity, which is what the walk needs)
                ivs = eqn.invars[-len(sj.invars):] if sj.invars else []
                for sv, ov in zip(sj.invars, ivs):
                    env[sv] = taint(ov)
                    if not isinstance(ov, jax_core.Literal) \
                            and ov in refmap:
                        refmap[sv] = refmap[ov]
                sub_outs = _walk_kernel(sj, env, refmap, guard,
                                        f"{scope}/{prim}", accesses)
                if len(sub_outs) == len(eqn.outvars):
                    out_taint = _union(out_taint, *sub_outs)
            set_out(eqn, out_taint)
    return [taint(v) for v in jaxpr.outvars]


def _kernel_accesses(site: PallasSite) -> list[_Access]:
    refmap = {}
    for role in ("in", "out"):
        for v in site.kernel_refs(role):
            refmap[v] = v
    accesses: list[_Access] = []
    _walk_kernel(site.kernel, {}, refmap, None, site.scope, accesses)
    return accesses


# ---------------------------------------------------------------------------
# KRACE
# ---------------------------------------------------------------------------

def check_kernel_race(graph_or_sites, *, name: str = "") -> list[Finding]:
    """KRACE: revisited blocks must accumulate, never clobber."""
    findings: list[Finding] = []
    for site in sites_of(graph_or_sites):
        accesses = _kernel_accesses(site)
        in_refs = site.kernel_refs("in")
        out_refs = site.kernel_refs("out")
        aliased_inputs = {i for i, _ in site.input_output_aliases}

        for pos, ref in enumerate(in_refs):
            if pos in aliased_inputs:
                continue
            if any(a.ref is ref and a.kind in ("write", "accum")
                   for a in accesses):
                findings.append(Finding(
                    "krace", "input-write", site.scope,
                    f"{site.name}/in[{pos}]",
                    f"{site.name}: kernel writes input ref [{pos}] with "
                    "no declared input_output_alias — aliasing an "
                    "operand the pipeline may still be streaming is a "
                    "race"))

        for i_in, i_out in site.input_output_aliases:
            if i_in < len(site.inputs) and i_out < len(site.outputs):
                vin = site.visits(site.inputs[i_in])
                vout = site.visits(site.outputs[i_out])
                if vin != vout:
                    findings.append(Finding(
                        "krace", "alias-mismatch", site.scope,
                        f"{site.name} alias in[{i_in}]->out[{i_out}]",
                        f"{site.name}: declared input_output_alias "
                        f"({i_in} -> {i_out}) but the two index maps "
                        "visit different blocks — reads and writes of "
                        "the shared buffer interleave across grid "
                        "steps"))

        for pos, block in enumerate(site.outputs):
            ref = out_refs[pos]
            revisit = site.revisit_axes(block)
            if not revisit:
                continue
            ref_acc = [a for a in accesses if a.ref is ref]
            reads = [a for a in ref_acc if a.kind in ("read", "accum")]
            for a in ref_acc:
                if (a.kind == "write" and not a.conditional
                        and ref not in a.value_reads):
                    findings.append(Finding(
                        "krace", "unguarded-overwrite", a.scope,
                        f"{_blk(site, block)} revisited along axes "
                        f"{sorted(revisit)}",
                        f"{_blk(site, block)}: grid revisits this block "
                        f"along axes {sorted(revisit)} but the kernel "
                        "overwrites it unconditionally with a value "
                        "independent of the ref — later steps clobber "
                        "earlier ones; accumulate, or guard the write "
                        "with pl.when on the revisit step"))
                    break
            if reads and not any(
                    a.kind in ("write", "accum") and a.conditional
                    and a.guard_axes & revisit for a in ref_acc):
                findings.append(Finding(
                    "krace", "missing-init", site.scope,
                    f"{_blk(site, block)} revisited along axes "
                    f"{sorted(revisit)}",
                    f"{_blk(site, block)}: the kernel reads this "
                    "revisited accumulator but never writes it under a "
                    "first-visit predicate — the first grid step "
                    "consumes uninitialized VMEM; add "
                    "pl.when(pid == 0) initialization"))
    return findings


# ---------------------------------------------------------------------------
# KVMEM
# ---------------------------------------------------------------------------

_SUBLANE = {1: 32, 2: 16, 4: 8, 8: 8}


def check_kernel_vmem(graph_or_sites, *,
                      max_bytes: float = VMEM_BUDGET_BYTES,
                      name: str = "") -> list[Finding]:
    """KVMEM: per-grid-step working set + lane/sublane alignment."""
    findings: list[Finding] = []
    for site in sites_of(graph_or_sites):
        # Pallas double-buffers streamed blocks (compute on one while
        # the DMA fills the other); scratch is single-buffered.
        step = sum(2 * b.block_bytes for b in site.blocks)
        step += sum(int(np.prod(shape, dtype=np.int64)) * dt.itemsize
                    for shape, dt in site.scratch_shapes)
        if step > max_bytes:
            findings.append(Finding(
                "kvmem", "working-set", site.scope,
                " + ".join(f"{_blk(site, b)}{b.block_shape}"
                           for b in site.blocks),
                f"{site.name}: per-grid-step VMEM working set "
                f"{step / 2**20:.2f} MiB (double-buffered blocks + "
                f"scratch) exceeds the budget "
                f"{max_bytes / 2**20:.2f} MiB"))
        for block in site.blocks:
            bad = []
            bs, ar = block.block_shape, block.array_shape
            lane = 128
            sub = _SUBLANE.get(jnp.dtype(block.dtype).itemsize, 8)
            if bs and bs[-1] % lane and bs[-1] != ar[-1]:
                bad.append(f"lane dim {bs[-1]} (want %{lane} or full "
                           f"{ar[-1]})")
            if len(bs) >= 2 and bs[-2] % sub and bs[-2] != ar[-2]:
                bad.append(f"sublane dim {bs[-2]} (want %{sub} or full "
                           f"{ar[-2]})")
            if bad:
                findings.append(Finding(
                    "kvmem", "misaligned-block", site.scope,
                    f"{_blk(site, block)} block {bs} of array {ar} "
                    f"[{block.dtype}]",
                    f"{_blk(site, block)}: block shape {bs} breaks the "
                    f"{block.dtype} tiling constraint: {'; '.join(bad)} "
                    "— Mosaic pads each tile, silently inflating VMEM "
                    "and masking the arithmetic"))
    return findings


# ---------------------------------------------------------------------------
# KPRECISION
# ---------------------------------------------------------------------------

def check_kernel_precision(graph_or_sites, *,
                           name: str = "") -> list[Finding]:
    """KPRECISION: fp32 MXU accumulation + fp32 cross-step accumulators."""
    from repro.analysis.rules import Graph, check_precision

    findings: list[Finding] = []
    for site in sites_of(graph_or_sites):
        inner = check_precision(
            Graph(site.name, jax_core.ClosedJaxpr(site.kernel, ())))
        findings += [dataclasses.replace(f, rule="kprecision")
                     for f in inner]
        accesses = _kernel_accesses(site)
        out_refs = site.kernel_refs("out")
        for pos, block in enumerate(site.outputs):
            if not site.revisit_axes(block) or not _is_low(block.dtype):
                continue
            ref = out_refs[pos]
            if any(a.ref is ref and a.kind in ("read", "accum")
                   for a in accesses):
                findings.append(Finding(
                    "kprecision", "low-precision-accumulator",
                    site.scope,
                    f"{_blk(site, block)} dtype={block.dtype}",
                    f"{_blk(site, block)}: this ref carries state "
                    "across revisiting grid steps but is "
                    f"{jnp.dtype(block.dtype).name} — cross-step "
                    "accumulation loses mass every store; keep the "
                    "accumulator fp32 and cast once on the way out"))
    return findings


# ---------------------------------------------------------------------------
# KSENTINEL
# ---------------------------------------------------------------------------

def _nonfinite_literals(jaxpr, scope):
    for eqn in jaxpr.eqns:
        for v in eqn.invars:
            if isinstance(v, jax_core.Literal):
                val = np.asarray(v.val)
                if (np.issubdtype(val.dtype, np.floating)
                        and not np.all(np.isfinite(val))):
                    yield eqn, scope, val
        for p in eqn.params.values():
            ps = p if isinstance(p, (tuple, list)) else (p,)
            for sub in ps:
                if isinstance(sub, jax_core.ClosedJaxpr):
                    sub = sub.jaxpr
                if isinstance(sub, jax_core.Jaxpr):
                    yield from _nonfinite_literals(
                        sub, f"{scope}/{eqn.primitive.name}")


def check_kernel_sentinel(graph_or_sites, *, mask_inputs=None,
                          name: str = "") -> list[Finding]:
    """KSENTINEL: finite sentinels only; masks consumed as traced refs.

    ``mask_inputs``: input operand positions that carry a membership
    mask — each must actually be read by the kernel body (a mask that
    is accepted but ignored silently aggregates absent workers, the
    kernel-level twin of the MASK rule's ``<unused>`` finding).
    """
    findings: list[Finding] = []
    for site in sites_of(graph_or_sites):
        seen_vals: set = set()
        for eqn, scope, val in _nonfinite_literals(site.kernel,
                                                   site.scope):
            tag = (scope, float(np.ravel(val)[0]))
            if tag in seen_vals:
                continue
            seen_vals.add(tag)
            findings.append(Finding(
                "ksentinel", "nonfinite-sentinel", scope,
                f"{site.name}: {eqn.primitive.name} consumes literal "
                f"{np.ravel(val)[0]}",
                f"{site.name}: non-finite constant "
                f"{np.ravel(val)[0]} inside the kernel body — inf "
                "sentinels turn masked lanes into NaNs under "
                "subtraction/0*inf; use a finite sentinel "
                "(-1e30 / finfo.max)"))
        if mask_inputs:
            accesses = _kernel_accesses(site)
            in_refs = site.kernel_refs("in")
            for pos in mask_inputs:
                if pos >= len(in_refs):
                    continue
                ref = in_refs[pos]
                if not any(a.ref is ref and a.kind == "read"
                           for a in accesses):
                    findings.append(Finding(
                        "ksentinel", "mask-unread", site.scope,
                        f"{site.name}/in[{pos}]",
                        f"{site.name}: membership-mask operand "
                        f"[{pos}] is never read by the kernel body — "
                        "inactive workers would silently participate"))
    return findings


# ---------------------------------------------------------------------------
# composite entry point (what @contract and the sweep call)
# ---------------------------------------------------------------------------

def check_kernels(graph_or_jaxpr, *, vmem_budget: float = VMEM_BUDGET_BYTES,
                  mask_inputs=None, expect_sites: int | None = None,
                  name: str = "") -> list[Finding]:
    """Run every kernel rule family over the graph's pallas_call sites.

    ``expect_sites`` is detector sanity (mirrors SHAPE's
    ``require_dims``): a sweep entry that promises to lint N kernels but
    traces a graph with a different count is not looking at the graph it
    thinks it is.
    """
    sites = sites_of(graph_or_jaxpr)
    findings: list[Finding] = []
    if expect_sites is not None and len(sites) != expect_sites:
        findings.append(Finding(
            "ktiling", "<site-count>", name or "entry",
            f"found {len(sites)} pallas_call site(s): "
            f"{[s.name for s in sites]}",
            f"expected {expect_sites} pallas_call site(s) in the traced "
            f"graph, found {len(sites)} — the kernel lint is not seeing "
            "the kernels it claims to check"))
    findings += check_kernel_tiling(sites, name=name)
    findings += check_kernel_race(sites, name=name)
    findings += check_kernel_vmem(sites, max_bytes=vmem_budget, name=name)
    findings += check_kernel_precision(sites, name=name)
    findings += check_kernel_sentinel(sites, mask_inputs=mask_inputs,
                                      name=name)
    return findings


K_RULES = {
    "ktiling": check_kernel_tiling,
    "krace": check_kernel_race,
    "kvmem": check_kernel_vmem,
    "kprecision": check_kernel_precision,
    "ksentinel": check_kernel_sentinel,
}
