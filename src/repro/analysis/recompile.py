"""RECOMPILE: the cache-size harness — the one implementation of
``_cache_size() == 1``.

Traced-vs-static hazards are a *runtime* property of a jitted entry
point: a membership mask baked in as a static Python value, a step index
branched on in Python, a shape derived from data — all compile a fresh
executable per distinct value.  The invariant the repo has relied on
since PR 4 (``tests/test_membership.py``, ``benchmarks/
membership_churn.py``) is that a correctly traced entry point compiles
exactly once across every argument variant.  This module generalizes
that assert to any entry point and any variant sweep, with structured
findings; the old ad-hoc ``fn._cache_size() == 1`` asserts route through
here.
"""

from __future__ import annotations

from repro.analysis.findings import ContractViolation, Finding
from repro.analysis.rules import RULES

__all__ = ["cache_size", "check_recompile", "assert_no_recompile"]


def cache_size(fn) -> int:
    """Number of compiled executables a ``jax.jit`` function holds.

    Accepts the jitted callable itself or anything wrapping one that
    forwards ``_cache_size`` (jax's own private-but-stable probe — kept
    in exactly one place so a jax rename is a one-line fix).
    """
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        raise TypeError(
            f"cache_size: {fn!r} does not expose a compilation cache — "
            "pass the jax.jit-wrapped callable itself")
    return probe()


def check_recompile(fn, variants=(), *, name: str | None = None,
                    max_compiles: int = 1) -> list[Finding]:
    """Call ``fn`` over ``variants`` and flag excess compilations.

    Args:
      fn: a ``jax.jit``-wrapped entry point.
      variants: iterable of argument tuples; each is invoked as
        ``fn(*v)``.  Pass ``()`` to only inspect the cache as-is (the
        caller already drove the function).
      name: entry-point label for the finding.
      max_compiles: allowed executable count (1 = fully traced).
    Returns:
      ``[]`` when the cache stayed within budget, else one ``recompile``
      finding carrying the observed compile count.
    """
    name = name or getattr(fn, "__name__", "entry")
    for v in variants:
        fn(*v)
    n = cache_size(fn)
    if n <= max_compiles:
        return []
    return [Finding(
        "recompile", "jit-cache", name,
        f"cache_size={n} after {len(tuple(variants)) or 'caller-driven'} "
        "variant(s)",
        f"{name} compiled {n}x (budget {max_compiles}) — some argument "
        "is consumed as a static Python value instead of a traced "
        "operand")]


def assert_no_recompile(fn, variants=(), *, name: str | None = None,
                        max_compiles: int = 1) -> None:
    """Raise :class:`ContractViolation` if ``fn`` recompiled."""
    findings = check_recompile(fn, variants, name=name,
                               max_compiles=max_compiles)
    if findings:
        raise ContractViolation(findings, name=name or "recompile")


RULES["recompile"] = check_recompile
