"""The rule registry: static-analysis passes over jaxprs and compiled HLO.

Each rule is a function ``(Graph | domain object, **params) -> list[Finding]``
registered under a family id.  The families (see docs/static_analysis.md
for the catalog):

``shape``
    No tensor dimension may exceed a declared bound (``max_dim`` — the
    rank-p solver's no-dim-beyond-p invariant), and declared dimensions
    must be absent (``forbidden_dims`` — per-device full-coordinate
    widths under a mesh) / present (``require_dims`` — detector sanity:
    the per-shard widths must actually show up).
``precision``
    ``dot_general`` (and sum-accumulating ops: ``reduce_sum``,
    ``scatter-add``, ``cumsum``, convolutions) whose operands are
    bf16/fp16 must accumulate in >= fp32 (``preferred_element_type`` on
    dots; an upcast before the reduce otherwise) — detected as a
    low-precision *output* of a low-precision contraction, the exact bug
    class ``tree_combine`` and the sketch rescale fixed by hand.
``transfer``
    No host callbacks or device transfers inside a jitted hot path.
``mask``
    The membership mask must be consumed as a *traced* operand — a
    Python branch on it (concretization) or silently ignoring it are
    both findings.
``collectives``
    Per-device collective byte volume (trip-count-corrected, via
    :mod:`repro.analysis.hlo`) must stay under a declared budget.

``recompile`` is the sixth family; being a runtime property it lives in
:mod:`repro.analysis.recompile` (the registry lists it for the catalog).

Jaxpr-level rules recurse into every sub-jaxpr (pjit bodies, scan/while
bodies, custom-vjp branches), so a rule sees through ``jax.jit`` wrappers
and control flow.  HLO-level rules see the compiled, SPMD-partitioned
module — shapes there are per-device, which is what makes the
no-full-width check meaningful.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import core as jax_core

from repro.analysis.findings import Finding
from repro.analysis.hlo import (COMP_HEADER_RE, DTYPE_BYTES, SHAPE_RE,
                                parse_collectives)

__all__ = ["Graph", "capture", "check_shape", "check_precision",
           "check_transfer", "check_mask", "check_collectives",
           "full_width_dims", "RULES"]


# ---------------------------------------------------------------------------
# capture: one entry point -> (jaxpr, compiled HLO)
# ---------------------------------------------------------------------------

@dataclass
class Graph:
    """One traced/compiled entry point, ready for the rules.

    ``jaxpr`` is the closed jaxpr of a no-argument thunk (inputs appear
    as constvars — the rules only walk equations, so that is immaterial);
    ``hlo`` is the compiled post-SPMD-partition HLO text, or ``None``
    when only trace-level rules are wanted.
    """

    name: str
    jaxpr: jax_core.ClosedJaxpr | None = None
    hlo: str | None = None


def capture(fn, *args, name: str | None = None, compile: bool = True,
            **kwargs) -> Graph:
    """Trace (and optionally compile) ``fn(*args, **kwargs)`` for analysis.

    Non-array arguments (configs, meshes, strings) are closed over, so
    any signature works.  For entry points that need explicit input
    shardings, build the :class:`Graph` by hand from
    ``jit(...).lower(specs).compile().as_text()`` instead.
    """
    thunk = lambda: fn(*args, **kwargs)
    closed = jax.make_jaxpr(thunk)()
    hlo = None
    if compile:
        hlo = jax.jit(thunk).lower().compile().as_text()
    return Graph(name or getattr(fn, "__name__", "entry"), closed, hlo)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    for v in params.values():
        if isinstance(v, jax_core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax_core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for w in v:
                if isinstance(w, jax_core.ClosedJaxpr):
                    yield w.jaxpr
                elif isinstance(w, jax_core.Jaxpr):
                    yield w


def iter_eqns(jaxpr: jax_core.Jaxpr, scope: str = "entry"):
    """Yield ``(eqn, scope)`` over the jaxpr and every nested sub-jaxpr."""
    for eqn in jaxpr.eqns:
        yield eqn, scope
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, f"{scope}/{eqn.primitive.name}")


def _shaped(aval):
    return getattr(aval, "shape", None) is not None and hasattr(aval, "dtype")


# ---------------------------------------------------------------------------
# SHAPE
# ---------------------------------------------------------------------------

def _hlo_typed_lines(hlo_text: str):
    """Yield ``(computation, line, dims_in_line)`` for every HLO op line."""
    comp = "<preamble>"
    for raw in hlo_text.splitlines():
        line = raw.strip()
        hm = COMP_HEADER_RE.match(line)
        if hm:
            comp = hm.group(1)
            continue
        dims = []
        for dt, ds in SHAPE_RE.findall(line):
            if dt not in DTYPE_BYTES:
                continue
            dims += [int(d) for d in ds.split(",") if d]
        if dims:
            yield comp, line, dims


def check_shape(graph: Graph, *, max_dim: int | None = None,
                forbidden_dims=(), require_dims=()) -> list[Finding]:
    """SHAPE: bound / forbid / require tensor dimensions.

    Prefers the compiled HLO when present (per-device, post-partition
    shapes — the only level where the no-full-width invariant means
    anything); falls back to jaxpr avals otherwise (enough for
    ``max_dim``-style blow-up checks, and cheap — no compile).
    """
    forbidden = set(forbidden_dims)
    required = set(require_dims)
    findings: list[Finding] = []
    seen: set[int] = set()

    def offending(dims):
        bad = [d for d in dims if max_dim is not None and d > max_dim]
        bad += [d for d in dims if d in forbidden]
        return bad

    if graph.hlo is not None:
        for comp, line, dims in _hlo_typed_lines(graph.hlo):
            seen.update(dims)
            bad = offending(dims)
            if bad:
                op = line.split("=", 1)[-1].strip().split("(", 1)[0]
                op = op.split()[-1] if op.split() else "?"
                findings.append(Finding(
                    "shape", op, comp, line,
                    f"tensor dimension(s) {sorted(set(bad))} violate the "
                    f"shape contract (max_dim={max_dim}, "
                    f"forbidden={sorted(forbidden)})"))
    elif graph.jaxpr is not None:
        for eqn, scope in iter_eqns(graph.jaxpr.jaxpr):
            avals = [v.aval for v in list(eqn.outvars) + list(eqn.invars)
                     if hasattr(v, "aval") and _shaped(v.aval)]
            dims = [int(d) for a in avals for d in a.shape]
            seen.update(dims)
            bad = offending(dims)
            if bad:
                findings.append(Finding(
                    "shape", eqn.primitive.name, scope, str(eqn),
                    f"tensor dimension(s) {sorted(set(bad))} violate the "
                    f"shape contract (max_dim={max_dim}, "
                    f"forbidden={sorted(forbidden)})"))
    else:
        raise ValueError("check_shape: graph has neither jaxpr nor HLO")

    if required and not (required & seen):
        findings.append(Finding(
            "shape", "<absent>", graph.name, f"dims seen: {sorted(seen)[:20]}",
            f"none of the required dimensions {sorted(required)} appear — "
            "the detector is not looking at the graph it thinks it is"))
    return findings


def full_width_dims(tree, n_shards: int) -> tuple[set[int], set[int]]:
    """(forbidden, required) dims for the no-full-width-per-device check.

    For a worker-major pytree sharded ``n_shards`` ways over the
    coordinate axis: the full flat width of every cleanly-divisible leaf
    (and its leading coordinate dim), plus the concatenated total when
    every leaf divides, must be *absent* from per-device HLO; at least
    one per-shard width must be *present* (detector sanity).  Leaves
    whose width does not divide ``n_shards`` are excluded — padding makes
    their per-device shapes implementation-defined.
    """
    leaves = jax.tree.leaves(tree)
    forbidden: set[int] = set()
    required: set[int] = set()
    total, all_divide = 0, True
    for leaf in leaves:
        shape = tuple(getattr(leaf, "shape", ()))
        if len(shape) < 2:
            continue
        flat = math.prod(shape[1:])
        total += flat
        if flat % n_shards == 0 and flat // n_shards > 1:
            forbidden.add(flat)
            required.add(flat // n_shards)
            if shape[1] != flat and shape[1] % n_shards == 0 \
                    and shape[1] // n_shards > 1:
                forbidden.add(shape[1])
                required.add(shape[1] // n_shards)
        else:
            all_divide = False
    if all_divide and total and total % n_shards == 0:
        forbidden.add(total)
    return forbidden - required, required


# ---------------------------------------------------------------------------
# PRECISION
# ---------------------------------------------------------------------------

_LOW = (jnp.bfloat16, jnp.float16)
# ops that *accumulate* a sum: a low-precision accumulator here loses mass
_ACCUM_PRIMS = {"dot_general", "reduce_sum", "cumsum", "scatter-add",
                "conv_general_dilated"}
_HLO_DOT_RE = re.compile(r"=\s*(bf16|f16)\[[\d,]*\][^=]*\b(dot|convolution)\(")


def _is_low(dtype) -> bool:
    return any(dtype == jnp.dtype(d) for d in _LOW)


def check_precision(graph: Graph) -> list[Finding]:
    """PRECISION: low-precision inputs must accumulate in >= fp32.

    A ``dot_general`` / reduction whose operands are bf16/fp16 *and*
    whose output is bf16/fp16 accumulated in low precision — the fix is
    ``preferred_element_type=jnp.float32`` (dots) or an fp32 upcast
    before the reduce, casting only the result back down.
    """
    findings: list[Finding] = []
    if graph.jaxpr is not None:
        for eqn, scope in iter_eqns(graph.jaxpr.jaxpr):
            if eqn.primitive.name not in _ACCUM_PRIMS:
                continue
            in_dtypes = [v.aval.dtype for v in eqn.invars
                         if hasattr(v, "aval") and _shaped(v.aval)]
            out_dtypes = [v.aval.dtype for v in eqn.outvars
                          if _shaped(v.aval)]
            if any(_is_low(d) for d in in_dtypes) \
                    and all(_is_low(d) for d in out_dtypes) and out_dtypes:
                findings.append(Finding(
                    "precision", eqn.primitive.name, scope, str(eqn),
                    f"{eqn.primitive.name} on "
                    f"{'/'.join(str(d) for d in in_dtypes)} inputs "
                    "accumulates in low precision — use "
                    "preferred_element_type=jnp.float32 (dots) or upcast "
                    "before the reduction"))
    elif graph.hlo is not None:
        comp = "<preamble>"
        for raw in graph.hlo.splitlines():
            line = raw.strip()
            hm = COMP_HEADER_RE.match(line)
            if hm:
                comp = hm.group(1)
                continue
            m = _HLO_DOT_RE.search(line)
            if m:
                findings.append(Finding(
                    "precision", m.group(2), comp, line,
                    f"{m.group(2)} emits a {m.group(1)} result — the "
                    "contraction accumulates in low precision"))
    else:
        raise ValueError("check_precision: graph has neither jaxpr nor HLO")
    return findings


# ---------------------------------------------------------------------------
# TRANSFER
# ---------------------------------------------------------------------------

_TRANSFER_PRIMS = {"infeed", "outfeed", "copy_to_host_async"}


def _is_real_device_put(eqn) -> bool:
    # jnp ops insert no-op device_put[devices=[None]] around Python
    # literals; only an explicit target device is a transfer.
    return eqn.primitive.name == "device_put" and any(
        d is not None for d in eqn.params.get("devices", []))
_HLO_TRANSFER_OPS = {"send", "recv", "send-done", "recv-done", "infeed",
                     "outfeed"}
_HLO_CALLBACK_RE = re.compile(
    r'custom[-_]call\(.*custom_call_target="([^"]*(?:callback|host)[^"]*)"',
    re.IGNORECASE)


def check_transfer(graph: Graph) -> list[Finding]:
    """TRANSFER: no host callbacks / device transfers in a jitted hot path.

    Jaxpr level: callback primitives (``pure_callback``, ``io_callback``,
    ``debug_callback``, ...), infeed/outfeed, and ``device_put`` with an
    explicit target device (the no-op ``devices=[None]`` form jnp wraps
    Python literals in is ignored).  HLO level: send/recv/infeed/outfeed
    ops and custom-calls into the Python callback runtime.
    """
    findings: list[Finding] = []
    if graph.jaxpr is not None:
        for eqn, scope in iter_eqns(graph.jaxpr.jaxpr):
            name = eqn.primitive.name
            if "callback" in name or name in _TRANSFER_PRIMS \
                    or _is_real_device_put(eqn):
                findings.append(Finding(
                    "transfer", name, scope, str(eqn),
                    f"host transfer / callback primitive {name!r} inside "
                    "the jitted hot path — the step would synchronize "
                    "with the host every call"))
    if graph.hlo is not None:
        comp = "<preamble>"
        for raw in graph.hlo.splitlines():
            line = raw.strip()
            hm = COMP_HEADER_RE.match(line)
            if hm:
                comp = hm.group(1)
                continue
            m = re.search(r"=\s*[^=]*?\b([\w\-]+)\(", line)
            op = m.group(1) if m else ""
            if op in _HLO_TRANSFER_OPS:
                findings.append(Finding(
                    "transfer", op, comp, line,
                    f"HLO {op} — host/device transfer compiled into the "
                    "hot path"))
            cb = _HLO_CALLBACK_RE.search(line)
            if cb:
                findings.append(Finding(
                    "transfer", "custom-call", comp, line,
                    f"host callback custom-call {cb.group(1)!r} compiled "
                    "into the hot path"))
    return findings


# ---------------------------------------------------------------------------
# MASK
# ---------------------------------------------------------------------------

def check_mask(fn, mask, *, name: str = "entry") -> list[Finding]:
    """MASK: membership-mask discipline for ``fn(mask)``.

    ``fn`` must take the ``(W,)`` mask as its only argument (close over
    everything else).  Two findings are possible: the mask is consumed as
    a Python value (a branch forced concretization — membership changes
    would recompile or crash under jit), or the traced mask is ignored
    entirely (the "masked" path silently aggregates absent workers).
    """
    try:
        closed = jax.make_jaxpr(fn)(mask)
    except (jax.errors.TracerBoolConversionError,
            jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError) as e:
        return [Finding(
            "mask", "python-branch", name, str(e).splitlines()[0],
            "membership mask is consumed as a Python value — it must stay "
            "a traced operand so membership changes never recompile")]
    mask_vars = set(closed.jaxpr.invars)

    def used(jaxpr) -> bool:
        for eqn in jaxpr.eqns:
            if mask_vars & set(v for v in eqn.invars
                               if isinstance(v, jax_core.Var)):
                return True
        return False

    if not used(closed.jaxpr):
        return [Finding(
            "mask", "<unused>", name, f"invars: {closed.jaxpr.invars}",
            "membership mask is accepted but never consumed — absent "
            "workers would silently participate in the aggregate")]
    return []


# ---------------------------------------------------------------------------
# COLLECTIVES
# ---------------------------------------------------------------------------

def check_collectives(graph: Graph, total_devices: int, *,
                      max_bytes_per_device: float) -> list[Finding]:
    """COLLECTIVES: per-device collective byte budget.

    Uses the trip-count-corrected parser (:func:`repro.analysis.hlo.
    parse_collectives`) — scanned-layer graphs account their loops.
    """
    if graph.hlo is None:
        raise ValueError("check_collectives needs compiled HLO "
                         "(collectives only exist post-partitioning)")
    stats = parse_collectives(graph.hlo, total_devices)
    if stats.total_moved_bytes > max_bytes_per_device:
        return [Finding(
            "collectives", "total", graph.name, stats.summary(),
            f"per-device collective volume {stats.total_moved_bytes:.3e} B "
            f"exceeds the budget {max_bytes_per_device:.3e} B")]
    return []


# ---------------------------------------------------------------------------
# registry (the catalog the CLI and docs enumerate)
# ---------------------------------------------------------------------------

RULES = {
    "shape": check_shape,
    "precision": check_precision,
    "transfer": check_transfer,
    "mask": check_mask,
    "collectives": check_collectives,
    # runtime family — see repro.analysis.recompile
    "recompile": None,
}


def _register_kernel_rules():
    # Deferred: pallas_rules imports this module (iter_eqns, Graph).
    from repro.analysis import pallas_rules as _pk

    RULES.update(_pk.K_RULES)


_register_kernel_rules()
