"""Checkpointing: crash-safe sharded-pytree save/restore (npz container).

Format v2: atomic temp+rename for every file, per-process meta (no
multi-writer clobber), and a size-carrying commit marker written last so
``latest_step`` never returns a partially written step dir.  See
docs/fault_tolerance.md for the protocol and resume invariants.
"""

from repro.checkpoint.checkpoint import (checkpoint_meta, latest_step,
                                         load_checkpoint, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "checkpoint_meta"]
