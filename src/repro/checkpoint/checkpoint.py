"""Crash-safe pytree checkpointing without external deps (format v2).

Layout: ``<dir>/step_<N>/`` holding, per writing process ``i``:

    state_<i>.npz     flattened leaves keyed by tree paths (bf16 leaves
                      round-trip through a uint16 view — numpy has no
                      native bf16)
    meta_<i>.json     step, treedef fingerprint, bf16 keys, leaf keys,
                      caller ``extra`` metadata (e.g. the LR horizon)
    commit_<i>.json   completeness marker: written *last*, records the
                      npz byte size

Crash-safety protocol: every file is written to a temp name in the step
dir and atomically renamed (``os.replace``), in the order npz -> meta ->
commit.  A crash at any point leaves a step dir without a valid commit
marker, which :func:`latest_step` and :func:`load_checkpoint` *skip* —
resume always lands on the newest step whose write completed.  The marker
stores the npz size, so a torn npz (e.g. a partial disk flush surviving a
power cut) is also rejected.  Arrays are gathered to host (fine for the
assigned scale of the CPU drivers; on a real pod each process writes its
own shard files via ``process_index`` — meta is namespaced per process
too, so concurrent writers never clobber each other's key manifests; v1
wrote one shared ``meta.json`` whose ``keys`` reflected whichever writer
landed last).

v1 compatibility: dirs written by the old format (shared ``meta.json``,
no marker) are still readable; they are treated as complete iff both
their meta and state files exist.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

FORMAT_VERSION = 2


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = {}
    for path, leaf in flat[0]:
        key = jax.tree_util.keystr(path)
        leaves[key] = leaf
    return leaves, flat[1]


def _atomic_write_bytes(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` via temp file + atomic rename + fsync."""
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def _state_name(process_index: int) -> str:
    return f"state_{process_index}.npz"


def _meta_name(process_index: int) -> str:
    return f"meta_{process_index}.json"


def _commit_name(process_index: int) -> str:
    return f"commit_{process_index}.json"


def save_checkpoint(directory: str, step: int, tree, *,
                    process_index: int = 0, extra: dict | None = None) -> str:
    """Atomically save ``tree`` as step ``step``.

    Args:
      directory: checkpoint root (created if missing).
      step: global step the state corresponds to.
      tree: arbitrary pytree of arrays (params, opt state, EF memory, ...).
      process_index: shard suffix for multi-process writers; state, meta
        and commit marker are all namespaced by it.
      extra: small JSON-able metadata stored in the meta file and returned
        by :func:`checkpoint_meta` — the train driver persists the LR
        horizon (``total_steps``) here so a resumed run keeps the original
        schedule.
    Returns:
      The step directory path.  The step only becomes visible to
      :func:`latest_step` once the commit marker lands (written last,
      atomically) — a crash mid-save leaves an ignorable partial dir.
    """
    leaves, treedef = _flatten(tree)
    step_dir = _step_dir(directory, step)
    os.makedirs(step_dir, exist_ok=True)
    arrays = {}
    bf16_keys = []
    for k, v in leaves.items():
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
            bf16_keys.append(k)
        arrays[k] = a
    fname = os.path.join(step_dir, _state_name(process_index))
    fd, tmp = tempfile.mkstemp(dir=step_dir, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **{k: v for k, v in arrays.items()})
            f.flush()
            os.fsync(f.fileno())
        shutil.move(tmp, fname)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    meta = {"format": FORMAT_VERSION, "step": step, "treedef": str(treedef),
            "bf16": bf16_keys, "keys": sorted(arrays),
            "extra": dict(extra or {})}
    _atomic_write_bytes(os.path.join(step_dir, _meta_name(process_index)),
                        json.dumps(meta).encode())
    commit = {"step": step, "state_bytes": os.path.getsize(fname)}
    _atomic_write_bytes(os.path.join(step_dir, _commit_name(process_index)),
                        json.dumps(commit).encode())
    return step_dir


def _is_complete(step_dir: str, process_index: int) -> bool:
    """True iff the step dir holds a finished write for ``process_index``."""
    state = os.path.join(step_dir, _state_name(process_index))
    if not os.path.isfile(state):
        return False
    has_meta = (os.path.isfile(os.path.join(step_dir,
                                            _meta_name(process_index)))
                or os.path.isfile(os.path.join(step_dir, "meta.json")))
    if not has_meta:
        return False
    marker = os.path.join(step_dir, _commit_name(process_index))
    if os.path.isfile(marker):
        try:
            with open(marker) as f:
                commit = json.load(f)
            return os.path.getsize(state) == commit["state_bytes"]
        except (ValueError, KeyError, OSError):
            return False
    # v1 fallback: shared meta.json, no marker — both files existing is the
    # best completeness signal that format offers.
    return os.path.isfile(os.path.join(step_dir, "meta.json"))


def _read_meta(step_dir: str, process_index: int) -> dict:
    path = os.path.join(step_dir, _meta_name(process_index))
    if not os.path.isfile(path):          # v1 layout
        path = os.path.join(step_dir, "meta.json")
    with open(path) as f:
        meta = json.load(f)
    meta.setdefault("format", 1)
    meta.setdefault("extra", {})
    return meta


def checkpoint_meta(directory: str, *, step: int | None = None,
                    process_index: int = 0) -> dict:
    """Meta dict (incl. ``extra``) of a step (default: latest complete)."""
    if step is None:
        step = latest_step(directory, process_index=process_index)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    return _read_meta(_step_dir(directory, step), process_index)


def load_checkpoint(directory: str, template, *, step: int | None = None,
                    process_index: int = 0):
    """Restore into the structure of ``template`` (shapes validated).

    ``step=None`` resumes from the newest *complete* step — partially
    written dirs (crash mid-save) are skipped, not crashed on.
    """
    if step is None:
        step = latest_step(directory, process_index=process_index)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    step_dir = _step_dir(directory, step)
    meta = _read_meta(step_dir, process_index)
    data = np.load(os.path.join(step_dir, _state_name(process_index)))
    leaves, _ = _flatten(template)
    out = {}
    for k, tmpl in leaves.items():
        a = data[k]
        if k in meta["bf16"]:
            a = a.view(jnp.bfloat16)
        if tuple(a.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{a.shape} vs {tmpl.shape}")
        out[k] = jnp.asarray(a, dtype=tmpl.dtype)
    # rebuild tree in template order
    flat = jax.tree_util.tree_flatten_with_path(template)
    rebuilt = [out[jax.tree_util.keystr(p)] for p, _ in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], rebuilt), meta["step"]


def latest_step(directory: str, *, process_index: int = 0,
                process_count: int | None = None) -> int | None:
    """Newest step with a *complete* write for ``process_index`` (or None).

    Incomplete dirs — no commit marker, or an npz whose size disagrees
    with the marker (torn write) — are skipped, so a crash mid-save can
    never be resumed from.

    Multi-process runs must pass ``process_count``: a step then counts
    only when complete for *every* process 0..process_count-1, so all
    restarting processes agree on the resume step even if the job died
    between two writers' commits (per-index completeness alone would let
    them resume from different steps and silently diverge).
    """
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    indices = (range(process_count) if process_count is not None
               else (process_index,))
    complete = [s for s in sorted(steps, reverse=True)
                if all(_is_complete(_step_dir(directory, s), i)
                       for i in indices)]
    return complete[0] if complete else None
