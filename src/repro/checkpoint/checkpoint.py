"""Pytree checkpointing without external deps.

Layout: ``<dir>/step_<N>/state.npz`` holding flattened leaves keyed by
their tree paths, plus ``meta.json`` with the step and tree structure
fingerprint.  Arrays are gathered to host (fine for the assigned scale of
the CPU drivers; on a real pod you would write per-shard files — the
function accepts a ``process_index`` suffix for that).  Atomic via
write-to-temp + rename.  ``bfloat16`` leaves round-trip through a uint16
view (numpy has no native bf16).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import numpy as np
import jax
import jax.numpy as jnp


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = {}
    for path, leaf in flat[0]:
        key = jax.tree_util.keystr(path)
        leaves[key] = leaf
    return leaves, flat[1]


def save_checkpoint(directory: str, step: int, tree, *,
                    process_index: int = 0) -> str:
    leaves, treedef = _flatten(tree)
    step_dir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(step_dir, exist_ok=True)
    arrays = {}
    bf16_keys = []
    for k, v in leaves.items():
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
            bf16_keys.append(k)
        arrays[k] = a
    fname = os.path.join(step_dir, f"state_{process_index}.npz")
    fd, tmp = tempfile.mkstemp(dir=step_dir, suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, **{k: v for k, v in arrays.items()})
    shutil.move(tmp, fname)
    meta = {"step": step, "treedef": str(treedef), "bf16": bf16_keys,
            "keys": sorted(arrays)}
    with open(os.path.join(step_dir, "meta.json"), "w") as f:
        json.dump(meta, f)
    return step_dir


def load_checkpoint(directory: str, template, *, step: int | None = None,
                    process_index: int = 0):
    """Restore into the structure of ``template`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(step_dir, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(step_dir, f"state_{process_index}.npz"))
    leaves, _ = _flatten(template)
    out = {}
    for k, tmpl in leaves.items():
        a = data[k]
        if k in meta["bf16"]:
            a = a.view(jnp.bfloat16)
        if tuple(a.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{a.shape} vs {tmpl.shape}")
        out[k] = jnp.asarray(a, dtype=tmpl.dtype)
    # rebuild tree in template order
    flat = jax.tree_util.tree_flatten_with_path(template)
    rebuilt = [out[jax.tree_util.keystr(p)] for p, _ in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], rebuilt), meta["step"]


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None
