"""Communication-efficient worker->server path: gradient codecs + EF.

The paper's headline claim is *simultaneous* robustness and communication
efficiency; this package supplies the communication half.  It sits between
the per-worker gradients and the aggregation layer:

  compressors     — pure encode/decode codec pairs over worker-major
                    pytrees (identity, signSGD + majority vote, top-k,
                    CountSketch) with declared bits-per-coordinate cost
                    models (the ``comm_bits`` metric is exact, not
                    sampled)
  error_feedback  — per-worker EF memory so biased codecs (signSGD,
                    top-k) still converge to the uncompressed fixed point

Dependency direction: ``repro.comm`` depends only on ``jax`` — the
distribution layer (``repro.dist``) builds on it, never the reverse.  The
integration points are ``repro.dist.aggregation.compressed_aggregate``
(codec x aggregator bridge, including the sketch->Gram fast path) and
``repro.dist.train_step`` (EF state threading + comm telemetry).

See docs/compression.md for each codec's cost model and when EF is
required.
"""

from repro.comm.compressors import (CODECS, Codec, CommConfig, dense_bits,
                                    get_codec, majority_vote)
from repro.comm.error_feedback import ef_encode_decode, init_ef

__all__ = ["CODECS", "Codec", "CommConfig", "dense_bits", "get_codec",
           "majority_vote", "ef_encode_decode", "init_ef"]
