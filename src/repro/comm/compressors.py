"""Worker->server gradient codecs: pure encode/decode pairs over pytrees.

Every codec compresses a *worker-major* gradient pytree (leaves ``(W, ...)``,
the output of ``vmap(grad)``) into a payload pytree — the bytes each worker
actually ships to the aggregation point — and decodes the payload back into
a worker-major estimate.  Both directions are pure jittable functions, so
the whole compressed train step stays one XLA program, and each codec
declares its bits-per-coordinate cost model so ``comm_bits`` telemetry is
exact rather than measured.

Implemented codecs (registry ``CODECS``; ``get_codec`` resolves a
:class:`CommConfig`):

  identity     — no-op reference point.  dtype-width bits/coord, unbiased.
  signsgd      — signSGD [Bernstein et al. 2018]: 1 bit/coord plus one
                 per-leaf fp32 scale (mean |g|) per worker.  Biased
                 (requires error feedback for convergence of general
                 aggregators); :func:`majority_vote` implements the
                 paper's majority-vote server decode for the pure
                 sign-server operating point.
  topk         — magnitude top-k sparsification: per leaf the k largest-
                 magnitude coordinates per worker travel as (index, value)
                 pairs.  Biased (error feedback required).
  countsketch  — CountSketch random projection [Charikar et al. 2002]:
                 each leaf's coordinates hash into ``k = ratio * n``
                 buckets with random signs.  The sketch is a sparse JL
                 transform, so sketch inner products are *unbiased*
                 estimates of gradient inner products — the payload can
                 feed the Gram-space aggregation path directly
                 (``gram_feed``) without ever decoding, which is how the
                 distributed runtime uses it (see repro.dist.train_step).

The hash/sign maps of ``countsketch`` are derived from ``CommConfig.seed``
only (shared by all workers, constant across steps), so encoding is
deterministic and the server can form Gram estimates without any
per-step coordination.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["CommConfig", "Codec", "CODECS", "get_codec", "dense_bits",
           "majority_vote"]


@dataclass(frozen=True)
class CommConfig:
    """Worker->server compression settings (see repro.dist.train_step).

    ``codec`` names a registry entry ('none' disables compression);
    ``error_feedback`` of ``None`` resolves to the codec's ``biased`` flag
    (biased codecs get EF by default, unbiased ones don't);
    ``topk_density`` is the kept fraction of coordinates per leaf;
    ``sketch_ratio`` is the CountSketch bucket count as a fraction of each
    leaf's coordinate count; ``seed`` fixes the sketch hash/sign maps.
    """

    codec: str = "none"
    error_feedback: bool | None = None
    topk_density: float = 1.0 / 16.0
    sketch_ratio: float = 1.0 / 16.0
    seed: int = 0

    @property
    def wants_ef(self) -> bool:
        """Resolved error-feedback switch (None -> biased-codec default)."""
        if self.codec == "none":
            return False
        codec = get_codec(self)
        if self.error_feedback is None:
            return codec.biased and not codec.gram_feed
        return self.error_feedback


class Codec:
    """Base codec: ``decode(encode(tree), tree)`` approximates ``tree``.

    Attributes:
      name: registry name.
      biased: True when ``E[decode(encode(g))] != g`` — such codecs need
        error feedback (repro.comm.error_feedback) to converge.
      gram_feed: True when the payload leaves are ``(W, k)`` matrices whose
        row inner products estimate gradient inner products, i.e. the
        payload can feed ``repro.dist.aggregation.tree_gram`` directly.
    """

    name: str = "?"
    biased: bool = False
    gram_feed: bool = False

    def encode(self, tree):
        """Worker-major gradient pytree -> payload pytree (leaves (W, ...))."""
        raise NotImplementedError

    def decode(self, payload, like):
        """Payload -> worker-major estimate with ``like``'s structure/shapes.

        Args:
          payload: output of :meth:`encode`.
          like: the original gradient pytree (abstract values suffice) —
            supplies leaf shapes/dtypes the payload does not carry.
        Returns:
          Pytree with ``like``'s treedef and leaf shapes ``(W, ...)``.
        """
        raise NotImplementedError

    def bits(self, like) -> float:
        """Total payload bits per step across all W workers (static)."""
        raise NotImplementedError


def _leaf_mats(tree):
    """Leaves flattened to (W, n_leaf) fp32 + original leaves (for shapes)."""
    leaves = jax.tree.leaves(tree)
    return [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves], \
        leaves


def _rebuild(like, flat_leaves):
    """Reshape per-leaf (W, n) fp32 matrices back into ``like``'s pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = [m.reshape(l.shape).astype(l.dtype)
           for m, l in zip(flat_leaves, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _dtype_bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def dense_bits(like) -> float:
    """Uncompressed worker->server bits per step (the comm_ratio baseline)."""
    return float(sum(l.size * _dtype_bits(l.dtype)
                     for l in jax.tree.leaves(like)))


class IdentityCodec(Codec):
    """Reference no-op codec: payload is the gradient tree itself."""

    name = "identity"

    def encode(self, tree):
        return tree

    def decode(self, payload, like):
        del like
        return payload

    def bits(self, like) -> float:
        return dense_bits(like)


class SignSGDCodec(Codec):
    """signSGD: per-coordinate sign + one fp32 scale per trailing row.

    The scale is the mean absolute value over each leaf's *last* axis (per
    worker), so the decode ``scale * sign(g)`` preserves the l1 mass of
    every row — the "scaled sign" variant whose EF-corrected form provably
    converges [Karimireddy et al. 2019].  Row granularity matters: a
    single per-leaf scale is dominated by the few hot rows of an
    embedding-style gradient (rare tokens carry near-zero rows), which
    makes the compression error — and the EF memory EF-SGD must recycle —
    much larger.  Cost: ~``1 + 32/d_last`` bits/coordinate.
    """

    name = "signsgd"
    biased = True

    def encode(self, tree):
        out = []
        for l in jax.tree.leaves(tree):
            M = l.astype(jnp.float32)
            out.append({"sign": jnp.sign(M).astype(jnp.int8),
                        "scale": jnp.mean(jnp.abs(M), axis=-1)})
        return out

    def decode(self, payload, like):
        leaves, treedef = jax.tree_util.tree_flatten(like)
        out = [(p["sign"].astype(jnp.float32)
                * p["scale"][..., None]).astype(l.dtype)
               for p, l in zip(payload, leaves)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def bits(self, like) -> float:
        total = 0.0
        for l in jax.tree.leaves(like):
            # 1 bit/coord + one fp32 scale per trailing row
            total += l.size + 32 * (l.size // l.shape[-1])
        return total


def majority_vote(payload, like):
    """signSGD-MV server decode: d = mean-scale * sign(sum_w sign_w).

    The pure sign-server operating point of Bernstein et al.: the server
    never sees magnitudes, only the element-wise majority of worker signs
    (itself a 1-bit downlink).  Robustness note: the vote is a per-
    coordinate median of signs, so up to ``(W-1)/2`` Byzantine workers
    cannot flip a coordinate the honest majority agrees on.

    Args:
      payload: output of ``SignSGDCodec.encode``.
      like: the original worker-major gradient pytree (shapes/dtypes).
    Returns:
      Aggregated gradient pytree (worker axis reduced away), each leaf
      ``mean_w(scale_w) * sign(sum_w sign_w)`` (scales per trailing row).
    """
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    for p, l in zip(payload, leaves):
        vote = jnp.sign(jnp.sum(p["sign"].astype(jnp.float32), axis=0))
        d = jnp.mean(p["scale"], axis=0)[..., None] * vote
        out.append(d.astype(l.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class TopKCodec(Codec):
    """Magnitude top-k sparsification: (index, value) pairs per worker.

    ``k = max(1, round(density * n_leaf))`` per leaf.  Cost model: each
    kept coordinate ships a fp32 value plus a ``ceil(log2 n_leaf)``-bit
    index (the tight entropy of a coordinate id; wire formats typically
    round up to 32 — the declared model keeps the tight count so the
    comm_bits metric lower-bounds any real implementation).
    """

    name = "topk"
    biased = True

    def __init__(self, density: float):
        if not 0.0 < density <= 1.0:
            raise ValueError(f"topk density must be in (0, 1], got {density}")
        self.density = density

    def _k(self, n: int) -> int:
        return max(1, min(n, round(self.density * n)))

    def encode(self, tree):
        mats, _ = _leaf_mats(tree)
        out = []
        for M in mats:
            k = self._k(M.shape[1])
            _, idx = jax.lax.top_k(jnp.abs(M), k)          # (W, k)
            val = jnp.take_along_axis(M, idx, axis=1)
            out.append({"idx": idx.astype(jnp.int32), "val": val})
        return out

    def decode(self, payload, like):
        leaves = jax.tree.leaves(like)
        flat = []
        for p, l in zip(payload, leaves):
            W = l.shape[0]
            n = l.size // W
            Z = jnp.zeros((W, n), jnp.float32)
            flat.append(Z.at[jnp.arange(W)[:, None], p["idx"]].set(p["val"]))
        return _rebuild(like, flat)

    def bits(self, like) -> float:
        total = 0.0
        for l in jax.tree.leaves(like):
            W = l.shape[0]
            n = l.size // W
            k = self._k(n)
            total += W * k * (32 + max(1, math.ceil(math.log2(n))))
        return total


class CountSketchCodec(Codec):
    """CountSketch: hash each coordinate into one of k signed buckets.

    For leaf coordinates ``i``, bucket ``h(i)`` and sign ``s(i)`` are fixed
    functions of ``seed`` (shared across workers and steps).  The encode of
    a row ``g`` is ``S[b] = sum_{h(i)=b} s(i) g[i]`` — a single scatter-add
    — and sketch inner products are unbiased: ``E[<Sg, Sg'>] = <g, g'>``.
    That makes the payload a drop-in Gram feed (``gram_feed``): FA / Krum /
    geomed selection runs on ``tree_gram(payload)`` with no decode.  The
    ``decode`` (unsketch ``g_hat[i] = s(i) S[h(i)]``) exists for the
    coordinate-wise aggregators and for error feedback, and is also
    unbiased per coordinate, but with variance ``~ ||g||^2 / k`` — hence
    ``biased = False`` yet EF still helps at small k.  Opting in via
    ``CommConfig(error_feedback=True)`` routes the aggregation bridge to
    the EF-compensated decode path even for Gram rules (the gram-feed
    fast path has no decode for EF to correct).
    """

    name = "countsketch"
    gram_feed = True

    def __init__(self, ratio: float, seed: int):
        if not 0.0 < ratio <= 1.0:
            raise ValueError(f"sketch ratio must be in (0, 1], got {ratio}")
        self.ratio = ratio
        self.seed = seed

    def _k(self, n: int) -> int:
        return max(1, min(n, round(self.ratio * n)))

    def _maps(self, n: int, leaf_idx: int):
        """(bucket (n,), sign (n,)) — trace-time constants from the seed."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), leaf_idx)
        kh, ks = jax.random.split(key)
        k = self._k(n)
        bucket = jax.random.randint(kh, (n,), 0, k)
        sign = jax.random.rademacher(ks, (n,), jnp.float32)
        return bucket, sign

    def encode(self, tree):
        mats, _ = _leaf_mats(tree)
        out = []
        for i, M in enumerate(mats):
            n = M.shape[1]
            bucket, sign = self._maps(n, i)
            k = self._k(n)
            S = jnp.zeros((M.shape[0], k), jnp.float32)
            out.append(S.at[:, bucket].add(M * sign[None, :]))
        return out

    def decode(self, payload, like):
        leaves = jax.tree.leaves(like)
        flat = []
        for i, (S, l) in enumerate(zip(payload, leaves)):
            n = l.size // l.shape[0]
            bucket, sign = self._maps(n, i)
            flat.append(S[:, bucket] * sign[None, :])
        return _rebuild(like, flat)

    def bits(self, like) -> float:
        total = 0.0
        for l in jax.tree.leaves(like):
            W = l.shape[0]
            n = l.size // W
            total += W * self._k(n) * 32
        return total


CODECS = ("identity", "signsgd", "topk", "countsketch")


def get_codec(cfg: CommConfig) -> Codec | None:
    """Resolve a CommConfig to a codec instance (None for 'none')."""
    if cfg.codec == "none":
        return None
    if cfg.codec == "identity":
        return IdentityCodec()
    if cfg.codec == "signsgd":
        return SignSGDCodec()
    if cfg.codec == "topk":
        return TopKCodec(cfg.topk_density)
    if cfg.codec == "countsketch":
        return CountSketchCodec(cfg.sketch_ratio, cfg.seed)
    raise KeyError(f"unknown codec {cfg.codec!r}; have "
                   f"{('none',) + CODECS}")
