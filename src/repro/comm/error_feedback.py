"""Error feedback (EF) memory for biased gradient compression.

Biased codecs (signSGD, top-k) discard a systematic part of every message;
plain SGD on the decoded messages then converges to a neighborhood whose
radius scales with the bias.  Error feedback [Seide et al. 2014; Karimireddy
et al. 2019 "EF-SGD"] fixes this by having every worker *remember* what the
codec dropped and add it back next round:

    h_t      = g_t + e_t            (gradient + carried memory)
    payload  = encode(h_t)          (what actually travels)
    e_{t+1}  = h_t - decode(payload)  (what got dropped, carried forward)

The memories telescope: summed over steps, everything each worker computed
is eventually transmitted, which restores convergence to the uncompressed
fixed point (the mean-recovery property ``tests/test_comm.py`` asserts
generatively).

The EF state is a worker-major pytree with the same treedef as the gradient
tree and leaves ``(W, *param_shape)`` in fp32 — per *worker* memory, so it
threads through the train step as an explicit carry (see
``repro.dist.train_step.build_train_step``; the step's signature grows an
``ef`` argument only when the active codec needs one).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.compressors import Codec

__all__ = ["init_ef", "ef_encode_decode"]


def init_ef(params, workers: int):
    """Zero EF memory for ``workers`` workers over ``params``' structure.

    Args:
      params: model parameter pytree (one replica, leaves ``(...)``).
      workers: W, the worker count (leading axis of the gradient tree).
    Returns:
      Pytree with ``params``' treedef and fp32 leaves ``(W, *leaf.shape)``.
    """
    return jax.tree.map(
        lambda p: jnp.zeros((workers,) + p.shape, jnp.float32), params)


def ef_encode_decode(codec: Codec, grads, ef, mask=None):
    """One EF round: compensate, encode, decode, update the memory.

    Args:
      codec: the active compressor.
      grads: worker-major gradient pytree (leaves ``(W, ...)``).
      ef: EF memory from :func:`init_ef` (same structure), or ``None`` to
        run the codec without compensation.
      mask: optional (W,) active-worker membership (bool or 0/1 float; see
        :mod:`repro.dist.membership`).  An inactive worker transmits
        nothing this round, so its memory must neither telescope nor be
        clobbered by whatever its masked-out gradient slot holds — its EF
        entry is *frozen* and resumes exactly where it left off when the
        worker rejoins.
    Returns:
      ``(decoded, payload, new_ef)`` — the decoded worker-major estimates
      the aggregator consumes, the raw payload (for gram-feeding codecs /
      telemetry), and the updated memory (``None`` iff ``ef`` was).
    """
    f32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    h = jax.tree.map(jnp.add, f32, ef) if ef is not None else f32
    payload = codec.encode(h)
    decoded = codec.decode(payload, h)
    if ef is None:
        return decoded, payload, None
    new_ef = jax.tree.map(jnp.subtract, h, decoded)
    if mask is not None:
        keep = mask.astype(bool)

        def freeze(new, old):
            sel = keep.reshape((keep.shape[0],) + (1,) * (new.ndim - 1))
            return jnp.where(sel, new, old)

        new_ef = jax.tree.map(freeze, new_ef, ef)
    return decoded, payload, new_ef
