"""Architecture registry: the 10 assigned configs + the paper's CNN task.

``get_config(arch_id)`` resolves the exact assigned configuration;
``reduce_for_smoke`` derives the CPU-runnable reduced variant (<=2 layers,
d_model <= 512, <=4 experts) used by the per-arch smoke tests.
"""

from __future__ import annotations

import dataclasses

from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.phi3_vision_4_2b import CONFIG as _phi3v
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.models.config import ModelConfig, MoESettings

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _xlstm, _smollm, _mixtral, _starcoder2, _stablelm, _command_r,
        _deepseek, _musicgen, _recurrentgemma, _phi3v,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    kinds_unique = tuple(dict.fromkeys(cfg.layer_kinds()))[:2]
    pattern = kinds_unique if len(kinds_unique) == 2 else kinds_unique * 2
    kv = 4 if cfg.num_kv_heads == cfg.num_heads else 2
    changes = dict(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=512 if cfg.d_ff > 0 else 0,
        vocab_size=512,
        block_pattern=pattern,
        rglru_width=256 if cfg.rglru_width else 0,
        window=min(cfg.window, 64) if cfg.window else None,
        compute_dtype="float32",   # CPU smoke: exact numerics
    )
    if cfg.moe is not None:
        changes["moe"] = MoESettings(
            num_experts=4, top_k=2, num_shared=min(cfg.moe.num_shared, 1),
            d_expert=128,
            # drop-free at smoke scale so decode==prefill exactly; capacity
            # dropping itself is covered by tests/test_moe.py
            capacity_factor=4.0)
        changes["moe_skip_first"] = cfg.moe_skip_first
        changes["dense_d_ff_first"] = 256 if cfg.moe_skip_first else 0
        if cfg.moe_skip_first:
            changes["num_layers"] = 3   # dense head + 2 moe body layers
    if cfg.frontend is not None:
        changes["num_prefix_embeds"] = 8
        changes["d_frontend"] = 32
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)
