"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.  No biases,
LayerNorm, SwiGLU, tied embeddings, RoPE theta 8e6.  The 256k vocab makes
the unembedding the memory hot-spot (see EXPERIMENTS §Roofline).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    norm="layernorm",
    act="silu",
    gated_mlp=True,
    use_bias=False,
    pos="rope",
    rope_theta=8e6,
    tie_embeddings=True,
)
