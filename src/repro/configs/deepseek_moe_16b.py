"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6 [arXiv:2401.06066].

28L d_model=2048 16H (MHA kv=16) d_ff=1408(expert) vocab=102400.
Fine-grained experts (d_expert=1408), 64 routed top-6 + 2 shared; layer 0
keeps a dense FFN of width 10944 (the paper's design).  The 64-expert axis
shards over the 16-way model axis (expert parallelism, 4 experts/device) —
the contrast with mixtral's within-expert TP is deliberate (see DESIGN §4).
"""

from repro.models.config import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoESettings(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    moe_skip_first=True,
    dense_d_ff_first=10944,
    norm="rmsnorm",
    act="silu",
    pos="rope",
)
