"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2,
sliding-window 4096.  Experts are tensor-parallel over d_ff
(expert_mlp -> model); the 8-expert axis is too small to shard 16 ways.
The SWA ring cache bounds decode memory: long_500k runs natively.
"""

from repro.models.config import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    moe=MoESettings(num_experts=8, top_k=2, d_expert=14336),
    window=4096,
    norm="rmsnorm",
    act="silu",
    pos="rope",
    rope_theta=1e6,
)
