"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 (EnCodec codebook).
Sinusoidal positions, LayerNorm, plain GELU MLP.  The EnCodec conv codec
and the T5 text encoder are the sanctioned STUB: input_specs supplies the
token stream plus a (B, 64, 768) conditioning-embedding prefix which the
frontend projector splices in front of the sequence.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    use_bias=True,
    pos="sinusoidal",
    frontend="audio",
    num_prefix_embeds=64,
    d_frontend=768,
)
