"""phi-3-vision-4.2b [vlm] — phi3-mini + CLIP
[hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.  RMSNorm, SwiGLU,
RoPE.  The CLIP ViT is the sanctioned STUB: input_specs supplies
(B, 256, 1024) patch embeddings; the projector (2-layer GELU MLP into
d_model) and the image-token splice ARE implemented (models/transformer
_embed_inputs), and the loss masks the image prefix.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    pos="rope",
    frontend="vision",
    num_prefix_embeds=256,
    d_frontend=1024,
)
