"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000.
Pattern (rglru, rglru, attn) x 12 + 2 trailing rglru (38 = 12*3 + 2 — the
tail exercises the non-period path).  Local attention window 2048,
GeGLU, RMSNorm, logit soft-cap 30, tied embeddings.  Decode state is
O(window + d_rnn): long_500k runs natively.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    window=2048,
    norm="rmsnorm",
    act="gelu",
    gated_mlp=True,
    pos="rope",
    tie_embeddings=True,
    logit_softcap=30.0,
    rglru_width=4096,
)
