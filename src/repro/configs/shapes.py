"""The four assigned input shapes and their ShapeDtypeStruct input specs."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def token_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                      *, with_labels: bool):
    """ShapeDtypeStruct stand-ins for one model batch (no allocation)."""
    S_tok = seq - (cfg.num_prefix_embeds if cfg.frontend else 0)
    spec = {"tokens": jax.ShapeDtypeStruct((batch, S_tok), jnp.int32)}
    if with_labels:
        spec["labels"] = jax.ShapeDtypeStruct((batch, S_tok), jnp.int32)
    if cfg.frontend is not None:
        spec["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_prefix_embeds, cfg.d_frontend), jnp.bfloat16)
    return spec


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                workers: int | None = None):
    """Input ShapeDtypeStructs for (arch x shape).

    train: per-worker batches with a leading worker axis (the FA worker
    dimension), {tokens, labels[, prefix_embeds]}.
    prefill: a request batch {tokens[, prefix_embeds]}.
    decode: one new token per sequence + the decode step counter; the KV /
    recurrent-state caches are supplied separately (see launch.dryrun).
    """
    if shape.kind == "train":
        assert workers, "training specs need the worker count"
        assert shape.global_batch % workers == 0
        per = shape.global_batch // workers
        leaf = token_batch_specs(cfg, per, shape.seq_len, with_labels=True)
        return {k: jax.ShapeDtypeStruct((workers,) + v.shape, v.dtype)
                for k, v in leaf.items()}
    if shape.kind == "prefill":
        return token_batch_specs(cfg, shape.global_batch, shape.seq_len,
                                 with_labels=False)
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                               jnp.int32),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)
