"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.  Llama recipe:
RMSNorm, SwiGLU, RoPE, tied embeddings.  Note 15 heads do not divide the
16-way model axis — attention activations replicate over heads while the
flattened qkv projection dim (960) shards; see configs/sharding notes.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    pos="rope",
    tie_embeddings=True,
)
