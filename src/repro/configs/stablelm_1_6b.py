"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.  LayerNorm,
SwiGLU, partial RoPE (25% of head_dim).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm="layernorm",
    act="silu",
    gated_mlp=True,
    pos="rope",
    rope_fraction=0.25,
)
