"""starcoder2-15b [dense] — GQA, RoPE [arXiv:2402.19173].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.  LayerNorm + bias,
plain (non-gated) GELU MLP per the StarCoder2 recipe.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    use_bias=True,
    pos="rope",
    rope_theta=1e5,
)
