"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
carry their own up/down projections (mLSTM pf=2, sLSTM pf=4/3), so there is
no separate FFN.  Period = 7 mLSTM : 1 sLSTM (the paper's xLSTM[7:1]), the
sLSTM placed at position 3 within the period as in the released models.
Long-context: O(1) recurrent state => runs long_500k natively.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm",
                   "mlstm", "mlstm", "mlstm", "mlstm"),
    pos="none",
    norm="rmsnorm",
    tie_embeddings=False,
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
)
