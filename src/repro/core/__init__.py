"""Core library: the paper's contribution (Flag Aggregator) + baselines.

Public API:
  FlagConfig, default_m           — hyper-parameters (paper defaults)
  flag_aggregate, flag_subspace   — paper-faithful dense IRLS (reference)
  fa_weights_from_gram,
  flag_aggregate_gram             — scalable Gram-space FA (TPU-native form)
  aggregators.AGGREGATORS         — baseline registry (mean..bulyan..flag)
  attacks.ATTACKS                 — Byzantine threat-model registry
"""

from repro.core import aggregators, attacks, beta_mle
from repro.core.flag import FlagConfig, default_m, flag_aggregate, flag_subspace
from repro.core.gram import fa_weights_from_gram, flag_aggregate_gram, gram_matrix

__all__ = [
    "FlagConfig", "default_m", "flag_aggregate", "flag_subspace",
    "fa_weights_from_gram", "flag_aggregate_gram", "gram_matrix",
    "aggregators", "attacks", "beta_mle",
]
