"""Baseline Byzantine-resilient aggregators the paper compares against.

All baselines take a worker-major gradient matrix ``Gw`` of shape ``(p, n)``
(one row per worker — the layout the distributed runtime produces via
``vmap(grad)``) and return the aggregated gradient of shape ``(n,)``.

Implemented (paper Sec. 3.1 + appendix E.2):
  mean, coordinate-wise median, coordinate-wise trimmed mean, MeaMed,
  Phocas, Krum, Multi-Krum, Bulyan, PCA-top-m (appendix E.2 baseline),
  geometric median (Weiszfeld), and the Flag Aggregator itself.

Everything is pure ``jax.numpy`` + ``lax`` (jit/vmap/grad-safe, no Python
control flow on traced values) so the same code runs inside the pjit'd
train step on a pod and in the CPU benchmarks.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.flag import FlagConfig, default_m
from repro.core.gram import fa_weights_from_gram, gram_matrix

__all__ = [
    "mean", "median", "trimmed_mean", "meamed", "phocas", "krum",
    "multi_krum", "bulyan", "pca_topm", "geometric_median", "flag",
    "get_aggregator", "AGGREGATORS", "pairwise_sq_dists", "krum_scores",
    "mean_around", "bulyan_select", "sq_dists_from_gram",
]


# ---------------------------------------------------------------------------
# coordinate-wise rules
# ---------------------------------------------------------------------------

def mean(Gw: jnp.ndarray, **_) -> jnp.ndarray:
    """Non-robust baseline (paper Fig. 2)."""
    return jnp.mean(Gw, axis=0)


def median(Gw: jnp.ndarray, **_) -> jnp.ndarray:
    """Coordinate-wise median [Yin et al. 2018]."""
    return jnp.median(Gw, axis=0)


def trimmed_mean(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Coordinate-wise trimmed mean: drop f largest + f smallest per coord."""
    p = Gw.shape[0]
    k = min(f, (p - 1) // 2)
    s = jnp.sort(Gw, axis=0)
    return jnp.mean(s[k:p - k], axis=0) if k > 0 else jnp.mean(s, axis=0)


def mean_around(Gw: jnp.ndarray, center: jnp.ndarray, k: int) -> jnp.ndarray:
    """Mean of the k values closest to ``center``, per coordinate.

    Public: the distributed tree aggregation (``repro.dist.aggregation``)
    applies this per leaf — coordinate-wise rules commute with the pytree
    split, so leafwise == flat exactly.
    """
    d = jnp.abs(Gw - center[None, :])
    # top-k smallest distances per coordinate via sort of (distance, value)
    order = jnp.argsort(d, axis=0)
    gathered = jnp.take_along_axis(Gw, order[:k], axis=0)
    return jnp.mean(gathered, axis=0)


def meamed(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Mean-around-median [Xie et al. 2018]: mean of p-f closest to median."""
    p = Gw.shape[0]
    return mean_around(Gw, jnp.median(Gw, axis=0), max(p - f, 1))


def phocas(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Phocas [Xie et al. 2018]: mean of p-f closest to the trimmed mean."""
    p = Gw.shape[0]
    return mean_around(Gw, trimmed_mean(Gw, f=f), max(p - f, 1))


# ---------------------------------------------------------------------------
# distance-based rules (Gram-computable: scalable on the pod)
# ---------------------------------------------------------------------------

def sq_dists_from_gram(K: jnp.ndarray) -> jnp.ndarray:
    """(p, p) squared pairwise distances from a Gram matrix K = G G^T."""
    dg = jnp.diag(K)
    return jnp.clip(dg[:, None] + dg[None, :] - 2.0 * K, 0.0)


def pairwise_sq_dists(Gw: jnp.ndarray) -> jnp.ndarray:
    """(p, p) squared distances from the Gram matrix (single O(n p^2) pass)."""
    return sq_dists_from_gram(gram_matrix(Gw.T))


def krum_scores(D2: jnp.ndarray, f: int) -> jnp.ndarray:
    """Krum score per worker: sum of its p-f-2 smallest distances to others."""
    p = D2.shape[0]
    k = max(p - f - 2, 1)
    # exclude self-distance by pushing the diagonal to +inf
    D2 = D2 + jnp.diag(jnp.full((p,), jnp.inf, D2.dtype))
    neg_small, _ = jax.lax.top_k(-D2, k)           # k smallest per row
    return -jnp.sum(neg_small, axis=1)


def krum(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Krum [Blanchard et al. 2017]: the single lowest-score gradient."""
    s = krum_scores(pairwise_sq_dists(Gw), f)
    return Gw[jnp.argmin(s)]


def multi_krum(Gw: jnp.ndarray, *, f: int = 1, q: int | None = None, **_):
    """Multi-Krum: average the q = p - f - 2 lowest-score gradients."""
    p = Gw.shape[0]
    q = q if q is not None else max(p - f - 2, 1)
    s = krum_scores(pairwise_sq_dists(Gw), f)
    _, idx = jax.lax.top_k(-s, q)
    return jnp.mean(Gw[idx], axis=0)


def bulyan_select(D2_all: jnp.ndarray, f: int) -> jnp.ndarray:
    """Bulyan's recursive Multi-Krum selection: theta = p - 2f worker
    indices picked lowest-Krum-score-first from squared pairwise distances.

    Distance-only, so the distributed runtime runs the identical selection
    from the tree Gram matrix without touching gradient payloads.
    """
    p = D2_all.shape[0]
    theta = max(p - 2 * f, 1)
    # Masked-out distances must dominate every real distance, but stay small
    # enough that  (count_masked * big + real_part)  still resolves real_part
    # in fp32 — each selection round includes the same number of masked
    # entries per row, so ordering is then decided by the real part.
    big = 4.0 * jnp.max(D2_all) + 1.0

    def select_one(carry, _):
        mask = carry                                   # True = still available
        # mask out already-selected workers from both axes
        D2 = jnp.where(mask[:, None] & mask[None, :], D2_all, big)
        s = krum_scores(D2, f)
        s = jnp.where(mask, s, jnp.inf)
        pick = jnp.argmin(s)
        return mask.at[pick].set(False), pick

    avail = jnp.ones((p,), bool)
    _, picks = jax.lax.scan(select_one, avail, None, length=theta)
    return picks


def bulyan(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Bulyan [El Mhamdi et al. 2018]: recursive Multi-Krum selection of
    theta = p - 2f gradients, then per-coordinate mean of the beta =
    theta - 2f values closest to the median (strong resilience needs
    p >= 4f + 3)."""
    p = Gw.shape[0]
    theta = max(p - 2 * f, 1)
    beta = max(theta - 2 * f, 1)
    picks = bulyan_select(pairwise_sq_dists(Gw), f)
    S = Gw[picks]                                      # (theta, n)
    return mean_around(S, jnp.median(S, axis=0), beta)


# ---------------------------------------------------------------------------
# subspace rules
# ---------------------------------------------------------------------------

def pca_topm(Gw: jnp.ndarray, *, m: int | None = None, **_) -> jnp.ndarray:
    """Appendix E.2 baseline: one unweighted FA step == PCA reconstruction.

    d = (1/p) Y Y^T G 1 with Y = top-m principal directions of the
    normalized gradient columns (single SVD, no IRLS, no regularizer).
    """
    cfg = FlagConfig(m=m, lam=0.0, regularizer="none", n_iter=1)
    c, _ = fa_weights_from_gram(gram_matrix(Gw.T), cfg)
    return Gw.T @ c.astype(Gw.dtype)


def flag(Gw: jnp.ndarray, *, cfg: FlagConfig = FlagConfig(), **_) -> jnp.ndarray:
    """The paper's Flag Aggregator (Gram-space solver)."""
    c, _ = fa_weights_from_gram(gram_matrix(Gw.T), cfg)
    return Gw.T @ c.astype(Gw.dtype)


def geometric_median(Gw: jnp.ndarray, *, n_iter: int = 8, eps: float = 1e-8, **_):
    """Weiszfeld iterations (extra baseline, not in the paper's table)."""
    def body(z, _):
        w = jax.lax.rsqrt(jnp.clip(jnp.sum((Gw - z[None, :]) ** 2, axis=1), eps))
        return jnp.sum(Gw * w[:, None], axis=0) / jnp.sum(w), None
    z0 = jnp.mean(Gw, axis=0)
    z, _ = jax.lax.scan(body, z0, None, length=n_iter)
    return z


AGGREGATORS: dict[str, Callable] = {
    "mean": mean,
    "median": median,
    "trimmed_mean": trimmed_mean,
    "meamed": meamed,
    "phocas": phocas,
    "krum": krum,
    "multi_krum": multi_krum,
    "bulyan": bulyan,
    "pca": pca_topm,
    "geomed": geometric_median,
    "flag": flag,
}


def get_aggregator(name: str) -> Callable:
    try:
        return AGGREGATORS[name]
    except KeyError:
        raise KeyError(f"unknown aggregator {name!r}; have {sorted(AGGREGATORS)}")
