"""Baseline Byzantine-resilient aggregators the paper compares against.

All baselines take a worker-major gradient matrix ``Gw`` of shape ``(p, n)``
(one row per worker — the layout the distributed runtime produces via
``vmap(grad)``) and return the aggregated gradient of shape ``(n,)``.

Implemented (paper Sec. 3.1 + appendix E.2):
  mean, coordinate-wise median, coordinate-wise trimmed mean, MeaMed,
  Phocas, Krum, Multi-Krum, Bulyan, PCA-top-m (appendix E.2 baseline),
  geometric median (Weiszfeld), and the Flag Aggregator itself.

Everything is pure ``jax.numpy`` + ``lax`` (jit/vmap/grad-safe, no Python
control flow on traced values) so the same code runs inside the pjit'd
train step on a pod and in the CPU benchmarks.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.flag import FlagConfig
from repro.core.gram import fa_weights_from_gram, gram_matrix
# Single source for the coordinate-wise statistics: the kernel oracles in
# kernels/coord_stats/ref.py (pure jnp, no Pallas import) ARE the
# implementations here — see that module's docstring.
from repro.kernels.coord_stats.ref import (meamed_ref, mean_around_ref,
                                           median_ref, phocas_ref,
                                           trimmed_mean_ref)

__all__ = [
    "mean", "median", "trimmed_mean", "meamed", "phocas", "krum",
    "multi_krum", "bulyan", "pca_topm", "geometric_median", "flag",
    "get_aggregator", "AGGREGATORS", "pairwise_sq_dists", "krum_scores",
    "mean_around", "bulyan_select", "sq_dists_from_gram",
    "masked_median", "masked_trimmed_mean", "masked_mean_around",
    "masked_krum_scores", "masked_selection_weights", "masked_bulyan_select",
    "MASKED_COORDWISE",
]


# ---------------------------------------------------------------------------
# coordinate-wise rules
# ---------------------------------------------------------------------------

def mean(Gw: jnp.ndarray, **_) -> jnp.ndarray:
    """Non-robust baseline (paper Fig. 2)."""
    return jnp.mean(Gw, axis=0)


def median(Gw: jnp.ndarray, **_) -> jnp.ndarray:
    """Coordinate-wise median [Yin et al. 2018]."""
    return median_ref(Gw)


def trimmed_mean(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Coordinate-wise trimmed mean: drop f largest + f smallest per coord."""
    return trimmed_mean_ref(Gw, f)


def mean_around(Gw: jnp.ndarray, center: jnp.ndarray, k: int) -> jnp.ndarray:
    """Mean of the k values closest to ``center``, per coordinate.

    Public: the distributed tree aggregation (``repro.dist.aggregation``)
    applies this per leaf — coordinate-wise rules commute with the pytree
    split, so leafwise == flat exactly.
    """
    return mean_around_ref(Gw, center, k)


def meamed(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Mean-around-median [Xie et al. 2018]: mean of p-f closest to median."""
    return meamed_ref(Gw, f)


def phocas(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Phocas [Xie et al. 2018]: mean of p-f closest to the trimmed mean."""
    return phocas_ref(Gw, f)


# ---------------------------------------------------------------------------
# distance-based rules (Gram-computable: scalable on the pod)
# ---------------------------------------------------------------------------

def sq_dists_from_gram(K: jnp.ndarray) -> jnp.ndarray:
    """(p, p) squared pairwise distances from a Gram matrix K = G G^T."""
    dg = jnp.diag(K)
    return jnp.clip(dg[:, None] + dg[None, :] - 2.0 * K, 0.0)


def pairwise_sq_dists(Gw: jnp.ndarray) -> jnp.ndarray:
    """(p, p) squared distances from the Gram matrix (single O(n p^2) pass)."""
    return sq_dists_from_gram(gram_matrix(Gw.T))


def krum_scores(D2: jnp.ndarray, f: int) -> jnp.ndarray:
    """Krum score per worker: sum of its p-f-2 smallest distances to others."""
    p = D2.shape[0]
    k = max(p - f - 2, 1)
    # exclude self-distance by pushing the diagonal to +inf
    D2 = D2 + jnp.diag(jnp.full((p,), jnp.inf, D2.dtype))
    neg_small, _ = jax.lax.top_k(-D2, k)           # k smallest per row
    return -jnp.sum(neg_small, axis=1)


def krum(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Krum [Blanchard et al. 2017]: the single lowest-score gradient."""
    s = krum_scores(pairwise_sq_dists(Gw), f)
    return Gw[jnp.argmin(s)]


def multi_krum(Gw: jnp.ndarray, *, f: int = 1, q: int | None = None, **_):
    """Multi-Krum: average the q = p - f - 2 lowest-score gradients."""
    p = Gw.shape[0]
    q = q if q is not None else max(p - f - 2, 1)
    s = krum_scores(pairwise_sq_dists(Gw), f)
    _, idx = jax.lax.top_k(-s, q)
    return jnp.mean(Gw[idx], axis=0)


def bulyan_select(D2_all: jnp.ndarray, f: int) -> jnp.ndarray:
    """Bulyan's recursive Multi-Krum selection: theta = p - 2f worker
    indices picked lowest-Krum-score-first from squared pairwise distances.

    Distance-only, so the distributed runtime runs the identical selection
    from the tree Gram matrix without touching gradient payloads.
    """
    p = D2_all.shape[0]
    theta = max(p - 2 * f, 1)
    # Masked-out distances must dominate every real distance, but stay small
    # enough that  (count_masked * big + real_part)  still resolves real_part
    # in fp32 — each selection round includes the same number of masked
    # entries per row, so ordering is then decided by the real part.
    big = 4.0 * jnp.max(D2_all) + 1.0

    def select_one(carry, _):
        mask = carry                                   # True = still available
        # mask out already-selected workers from both axes
        D2 = jnp.where(mask[:, None] & mask[None, :], D2_all, big)
        s = krum_scores(D2, f)
        s = jnp.where(mask, s, jnp.inf)
        pick = jnp.argmin(s)
        return mask.at[pick].set(False), pick

    avail = jnp.ones((p,), bool)
    _, picks = jax.lax.scan(select_one, avail, None, length=theta)
    return picks


def bulyan(Gw: jnp.ndarray, *, f: int = 1, **_) -> jnp.ndarray:
    """Bulyan [El Mhamdi et al. 2018]: recursive Multi-Krum selection of
    theta = p - 2f gradients, then per-coordinate mean of the beta =
    theta - 2f values closest to the median (strong resilience needs
    p >= 4f + 3)."""
    p = Gw.shape[0]
    theta = max(p - 2 * f, 1)
    beta = max(theta - 2 * f, 1)
    picks = bulyan_select(pairwise_sq_dists(Gw), f)
    S = Gw[picks]                                      # (theta, n)
    return mean_around(S, jnp.median(S, axis=0), beta)


# ---------------------------------------------------------------------------
# masked (dynamic worker subset) variants — the membership layer
# ---------------------------------------------------------------------------
#
# Each rule re-expressed over the *active* workers of a (W, ...) stack with a
# traced (W,) membership mask: the worker axis keeps its static size W, the
# active count W_a = sum(mask) is a traced value, and dynamic order
# statistics are realized as sort + gather-at-traced-index.  Membership
# changes therefore never change any array shape — the same compiled program
# serves every subset (asserted via compile counting in
# tests/test_membership.py), and each masked rule equals its unmasked
# counterpart applied to the active submatrix (also asserted there).

def _masked_count(mask: jnp.ndarray) -> jnp.ndarray:
    """Active-worker count as a traced int32 (at least 1)."""
    return jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)


def masked_median(Gw: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Per-coordinate median over the active rows of ``Gw (W, n)``."""
    S = jnp.sort(jnp.where(mask.astype(bool)[:, None], Gw, jnp.inf), axis=0)
    wa = _masked_count(mask)
    return 0.5 * (S[(wa - 1) // 2] + S[wa // 2])


def masked_trimmed_mean(Gw: jnp.ndarray, mask: jnp.ndarray, *,
                        f: int = 1) -> jnp.ndarray:
    """Per-coordinate trimmed mean over active rows: drop the f largest and
    f smallest active values (f capped at (W_a - 1) // 2, as unmasked)."""
    wa = _masked_count(mask)
    k = jnp.minimum(f, (wa - 1) // 2)
    S = jnp.sort(jnp.where(mask.astype(bool)[:, None], Gw, jnp.inf), axis=0)
    r = jnp.arange(Gw.shape[0])[:, None]
    sel = (r >= k) & (r < wa - k)
    return (jnp.sum(jnp.where(sel, S, 0.0), axis=0)
            / jnp.maximum(wa - 2 * k, 1))


def masked_mean_around(Gw: jnp.ndarray, center: jnp.ndarray,
                       k: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Mean of the ``k`` active values closest to ``center``, per coordinate
    (``k`` may be traced; inactive rows sort to +inf distance)."""
    d = jnp.where(mask.astype(bool)[:, None],
                  jnp.abs(Gw - center[None, :]), jnp.inf)
    order = jnp.argsort(d, axis=0)
    gathered = jnp.take_along_axis(Gw, order, axis=0)
    sel = jnp.arange(Gw.shape[0])[:, None] < k
    return jnp.sum(jnp.where(sel, gathered, 0.0), axis=0) / jnp.maximum(k, 1)


def _masked_meamed(Gw, mask, *, f=1):
    wa = _masked_count(mask)
    return masked_mean_around(Gw, masked_median(Gw, mask),
                              jnp.maximum(wa - f, 1), mask)


def _masked_phocas(Gw, mask, *, f=1):
    wa = _masked_count(mask)
    return masked_mean_around(Gw, masked_trimmed_mean(Gw, mask, f=f),
                              jnp.maximum(wa - f, 1), mask)


MASKED_COORDWISE: dict[str, Callable] = {
    "median": lambda Gw, mask, *, f=1: masked_median(Gw, mask),
    "trimmed_mean": masked_trimmed_mean,
    "meamed": _masked_meamed,
    "phocas": _masked_phocas,
}


def masked_krum_scores(D2: jnp.ndarray, f: int,
                       mask: jnp.ndarray) -> jnp.ndarray:
    """Krum scores over the active subset: each active worker sums its
    W_a - f - 2 smallest squared distances to *other active* workers
    (dynamic count via sort + cumulative positional mask); inactive
    workers score +inf."""
    W = D2.shape[0]
    mb = mask.astype(bool)
    wa = _masked_count(mask)
    valid = (mb[:, None] & mb[None, :]
             & ~jnp.eye(W, dtype=bool))
    S = jnp.sort(jnp.where(valid, D2, jnp.inf), axis=1)
    kk = jnp.clip(wa - f - 2, 1, jnp.maximum(wa - 1, 1))
    # active rows hold exactly W_a - 1 finite entries, and kk <= W_a - 1,
    # so the selected prefix is finite; inactive rows are all-inf -> inf.
    return jnp.sum(jnp.where(jnp.arange(W)[None, :] < kk, S, 0.0), axis=1)


def masked_selection_weights(D2: jnp.ndarray, name: str, f: int,
                             mask: jnp.ndarray) -> jnp.ndarray:
    """Krum / Multi-Krum combination weights over the active subset.

    Degenerate quorums stay safe: with a single active worker its score is
    +inf (it has no active peers to sum distances over), so scores are
    re-finited for active workers before the argmin/rank — selection can
    then never land on an inactive worker, and an all-inactive mask
    yields the zero weight vector (a no-op update) rather than silently
    applying a departed worker's garbage slot.
    """
    W = D2.shape[0]
    mb = mask.astype(bool)
    s = masked_krum_scores(D2, f, mask)
    s = jnp.where(mb, jnp.where(jnp.isfinite(s), s, 0.0), jnp.inf)
    if name == "krum":
        return (jax.nn.one_hot(jnp.argmin(s), W, dtype=D2.dtype)
                * mask.astype(D2.dtype))
    wa = _masked_count(mask)
    q = jnp.clip(wa - f - 2, 1, wa)
    rank = jnp.argsort(jnp.argsort(s))            # inactive (inf) rank last
    return (jnp.where(rank < q, 1.0 / q, 0.0)
            * mask.astype(D2.dtype)).astype(D2.dtype)


def masked_bulyan_select(D2_all: jnp.ndarray, f: int, mask: jnp.ndarray):
    """Bulyan's recursive selection over the active subset.

    Mirrors :func:`bulyan_select` exactly on the active submatrix: already-
    selected workers keep contributing the finite ``big`` sentinel to every
    row's score sum (same count per row, so ordering is decided by the real
    part), while *inactive* workers are excluded outright (+inf, never
    summed).  Runs W static rounds; rounds past theta = W_a - 2f are
    discarded via the take flag.

    Returns:
      ``(selected, theta)`` — a (W,) bool mask of the theta chosen workers
      and the traced selection count.
    """
    W = D2_all.shape[0]
    mb = mask.astype(bool)
    wa = _masked_count(mask)
    theta = jnp.clip(wa - 2 * f, 1, wa)
    kk = jnp.clip(wa - f - 2, 1, jnp.maximum(wa - 1, 1))
    active_pairs = mb[:, None] & mb[None, :] & ~jnp.eye(W, dtype=bool)
    big = 4.0 * jnp.max(jnp.where(active_pairs, D2_all, 0.0)) + 1.0

    def select_one(carry, r):
        avail = carry                                  # bool, still available
        valid = avail[:, None] & avail[None, :] & ~jnp.eye(W, dtype=bool)
        D2 = jnp.where(active_pairs,
                       jnp.where(valid, D2_all, big), jnp.inf)
        S = jnp.sort(D2, axis=1)
        s = jnp.sum(jnp.where(jnp.arange(W)[None, :] < kk, S, 0.0), axis=1)
        # a lone available worker has no peers to score against (+inf);
        # re-finite available scores so argmin can only land on one, and
        # only take picks that are genuinely available (an all-inactive
        # mask then selects nobody instead of worker 0's garbage slot).
        s = jnp.where(avail, jnp.where(jnp.isfinite(s), s, 0.0), jnp.inf)
        pick = jnp.argmin(s)
        take = (r < theta) & avail[pick]
        avail = avail & ~((jnp.arange(W) == pick) & take)
        return avail, (pick, take)

    _, (picks, takes) = jax.lax.scan(select_one, mb, jnp.arange(W))
    selected = jnp.zeros((W,), bool).at[picks].max(takes)
    return selected, theta


# ---------------------------------------------------------------------------
# subspace rules
# ---------------------------------------------------------------------------

def pca_topm(Gw: jnp.ndarray, *, m: int | None = None, **_) -> jnp.ndarray:
    """Appendix E.2 baseline: one unweighted FA step == PCA reconstruction.

    d = (1/p) Y Y^T G 1 with Y = top-m principal directions of the
    normalized gradient columns (single SVD, no IRLS, no regularizer).
    """
    cfg = FlagConfig(m=m, lam=0.0, regularizer="none", n_iter=1)
    c, _ = fa_weights_from_gram(gram_matrix(Gw.T), cfg)
    return Gw.T @ c.astype(Gw.dtype)


def flag(Gw: jnp.ndarray, *, cfg: FlagConfig = FlagConfig(), **_) -> jnp.ndarray:
    """The paper's Flag Aggregator (Gram-space solver)."""
    c, _ = fa_weights_from_gram(gram_matrix(Gw.T), cfg)
    return Gw.T @ c.astype(Gw.dtype)


def geometric_median(Gw: jnp.ndarray, *, n_iter: int = 8, eps: float = 1e-8, **_):
    """Weiszfeld iterations (extra baseline, not in the paper's table)."""
    def body(z, _):
        w = jax.lax.rsqrt(jnp.clip(jnp.sum((Gw - z[None, :]) ** 2, axis=1), eps))
        return jnp.sum(Gw * w[:, None], axis=0) / jnp.sum(w), None
    z0 = jnp.mean(Gw, axis=0)
    z, _ = jax.lax.scan(body, z0, None, length=n_iter)
    return z


AGGREGATORS: dict[str, Callable] = {
    "mean": mean,
    "median": median,
    "trimmed_mean": trimmed_mean,
    "meamed": meamed,
    "phocas": phocas,
    "krum": krum,
    "multi_krum": multi_krum,
    "bulyan": bulyan,
    "pca": pca_topm,
    "geomed": geometric_median,
    "flag": flag,
}


def get_aggregator(name: str) -> Callable:
    try:
        return AGGREGATORS[name]
    except KeyError:
        raise KeyError(
            f"unknown aggregator {name!r}; have {sorted(AGGREGATORS)}"
        ) from None
