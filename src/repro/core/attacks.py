"""Byzantine attack library (the paper's threat models, Sec. 3.1 + App. E.2).

Attacks transform a worker-major honest gradient matrix ``Gw (p, n)`` into
the matrix actually "received": the first ``f`` workers are Byzantine (which
workers are Byzantine is irrelevant to permutation-invariant aggregators;
tests cover shuffled placement too).  Everything is a pure function of
``(Gw, rng, f)`` so the simulation is deterministic and jit-safe, and can run
*inside* the distributed train step (each worker knows its index).

Implemented threat models:
  random      — uniformly random gradients (paper Figs. 2/4/9: "Byzantine
                workers send random gradients")
  gaussian    — N(0, sigma^2) gradients
  sign_flip   — 10x amplified sign-flipped gradients (App. E.2, Fig. 12b)
  zero        — send zeros (a degenerate failure)
  drop        — 10% of packet coordinates dropped/zeroed (Fig. 6a netem loss)
  ipm         — Fall of Empires inner-product manipulation (Fig. 12a):
                byz gradient = -eps * mean(honest)
  alie        — A Little Is Enough: mean + z * std of honest gradients
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["apply_attack", "ATTACKS", "byzantine_mask"]


def byzantine_mask(p: int, f: int) -> jnp.ndarray:
    """Boolean (p,) mask, True for Byzantine workers (the first f)."""
    return jnp.arange(p) < f


def _bmask(mask: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Broadcast the worker mask against an arbitrary-rank leaf (W, ...)."""
    return mask.reshape(mask.shape + (1,) * (g.ndim - 1))


def _honest_stats(Gw: jnp.ndarray, mask: jnp.ndarray):
    """Mean/std over honest workers only (what omniscient attackers use)."""
    w = _bmask(~mask, Gw).astype(Gw.dtype)
    denom = jnp.maximum(jnp.sum(w, axis=0), 1.0)
    mu = jnp.sum(Gw * w, axis=0) / denom
    var = jnp.sum(w * (Gw - mu[None]) ** 2, axis=0) / denom
    return mu, jnp.sqrt(var)


def _random(Gw, rng, mask, *, scale: float = 1.0):
    scale = scale * jnp.max(jnp.abs(Gw))
    noise = jax.random.uniform(rng, Gw.shape, Gw.dtype, -1.0, 1.0) * scale
    return jnp.where(_bmask(mask, Gw), noise, Gw)


def _gaussian(Gw, rng, mask, *, sigma: float = 1.0):
    sigma = sigma * jnp.std(Gw)
    noise = jax.random.normal(rng, Gw.shape, Gw.dtype) * sigma
    return jnp.where(_bmask(mask, Gw), noise, Gw)


def _sign_flip(Gw, rng, mask, *, scale: float = 10.0):
    del rng
    return jnp.where(_bmask(mask, Gw), -scale * Gw, Gw)


def _zero(Gw, rng, mask):
    del rng
    return jnp.where(_bmask(mask, Gw), jnp.zeros_like(Gw), Gw)


def _drop(Gw, rng, mask, *, loss_rate: float = 0.10):
    """Communication loss: each Byzantine link drops loss_rate of coords."""
    keep = jax.random.bernoulli(rng, 1.0 - loss_rate, Gw.shape)
    dropped = jnp.where(keep, Gw, 0.0)
    return jnp.where(_bmask(mask, Gw), dropped, Gw)


def _ipm(Gw, rng, mask, *, eps: float = 0.1):
    """Fall of Empires [Xie et al. 2020] with the paper's eps = 0.1."""
    del rng
    mu, _ = _honest_stats(Gw, mask)
    return jnp.where(_bmask(mask, Gw), -eps * mu[None], Gw)


def _alie(Gw, rng, mask, *, z: float = 1.5):
    """A Little Is Enough [Baruch et al. 2019]."""
    del rng
    mu, sd = _honest_stats(Gw, mask)
    return jnp.where(_bmask(mask, Gw), (mu - z * sd)[None], Gw)


def _none(Gw, rng, mask):
    del rng, mask
    return Gw


ATTACKS: dict[str, Callable] = {
    "none": _none,
    "random": _random,
    "gaussian": _gaussian,
    "sign_flip": _sign_flip,
    "zero": _zero,
    "drop": _drop,
    "ipm": _ipm,
    "alie": _alie,
}


def apply_attack(name: str, Gw: jnp.ndarray, rng: jax.Array, f: int, **kw):
    """Apply attack ``name`` with ``f`` Byzantine workers to ``Gw (p, ...)``."""
    if name not in ATTACKS:
        raise KeyError(f"unknown attack {name!r}; have {sorted(ATTACKS)}")
    mask = byzantine_mask(Gw.shape[0], f)
    return ATTACKS[name](Gw, rng, mask, **kw)


def apply_attack_tree(name: str, grads_w, rng: jax.Array, f: int, **kw):
    """Per-leaf attack on a worker-major gradient pytree (W, ...) leaves.

    The same Byzantine worker set corrupts every leaf; rng is folded per
    leaf so random attacks differ across tensors but stay deterministic."""
    if name not in ATTACKS:
        raise KeyError(f"unknown attack {name!r}; have {sorted(ATTACKS)}")
    leaves, treedef = jax.tree_util.tree_flatten(grads_w)
    mask = byzantine_mask(leaves[0].shape[0], f)
    out = [ATTACKS[name](leaf, jax.random.fold_in(rng, i), mask, **kw)
           for i, leaf in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)
