"""Beta-density maximum-likelihood machinery behind the Flag Aggregator.

The paper (Sec. 2.2) models the *explained variance* of worker ``i`` under a
candidate subspace ``Y`` as

    v_i = ||Y^T g~_i||^2 / 1  in [0, 1],      g~_i = g_i / ||g_i||,

and assumes v_i ~ Beta(alpha, beta).  The negative log-likelihood is

    NLL(Y) = -(alpha - 1) * sum_i log(v_i) - (beta - 1) * sum_i log(1 - v_i).

For (alpha, beta) = (1, 1/2) this reduces to  (1/2) sum_i log(1 - v_i) with a
negative sign, and the paper's Taylor trick  log(x) ~ a * x^(1/a) - a  (large
``a``) turns each term into a smooth l_a-norm-style penalty

    a * (1 - v_i)^(1/a) - a.

At a = 2 the loss is  sum_i sqrt(1 - v_i)  — the *Flag Median* objective —
which is what FA regularizes and solves with IRLS.  This module exposes the
generic pieces so the aggregator supports any (alpha, beta, a), not just the
paper's default.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "taylor_log",
    "beta_nll_terms",
    "beta_nll",
    "irls_weights",
]


def taylor_log(x: jnp.ndarray, a: float) -> jnp.ndarray:
    """Paper's smooth surrogate for ``log``:  log(x) ~ a * x**(1/a) - a.

    Exact as a -> inf; a=2 yields the sqrt losses used by Flag Median / FA.
    """
    return a * jnp.power(x, 1.0 / a) - a


def beta_nll_terms(
    v: jnp.ndarray,
    *,
    alpha: float = 1.0,
    beta: float = 0.5,
    a: float = 2.0,
    eps: float = 1e-12,
) -> jnp.ndarray:
    """Per-worker smoothed negative log-likelihood terms.

    With the Taylor surrogate, term_i =
        -(alpha-1) * [a * v_i**(1/a) - a]  - (beta-1) * [a * (1-v_i)**(1/a) - a].

    For the paper's (1, 1/2, 2):  term_i = sqrt(1 - v_i) + const.  Constants
    are dropped (they do not affect the argmin over Y).
    """
    v = jnp.clip(v, eps, 1.0 - eps)
    t = jnp.zeros_like(v)
    if alpha != 1.0:
        t = t - (alpha - 1.0) * a * jnp.power(v, 1.0 / a)
    if beta != 1.0:
        t = t - (beta - 1.0) * a * jnp.power(1.0 - v, 1.0 / a)
    return t


def beta_nll(v: jnp.ndarray, **kw) -> jnp.ndarray:
    """Total smoothed NLL (scalar)."""
    return jnp.sum(beta_nll_terms(v, **kw))


def irls_weights(
    v: jnp.ndarray,
    coef: jnp.ndarray,
    *,
    alpha: float = 1.0,
    beta: float = 0.5,
    a: float = 2.0,
    eps: float = 1e-10,
) -> jnp.ndarray:
    """IRLS majorizer weights for the smoothed Beta NLL.

    Each loss term  c * (1 - v)^(1/a)  (the beta part; plus the mirror-image
    alpha part in v) is majorized at the current iterate by a *linear*
    function of v with slope = d/dv of the term:

        d/dv [ c * -(beta-1) * a * (1-v)^(1/a) ] = c * (beta-1) * (1-v)^(1/a - 1)

    Minimizing the majorizer over the Stiefel manifold is a weighted-PCA
    problem with these (nonnegative) weights — the classical IRLS step that
    the paper's Algorithm 1 performs via repeated SVDs.  For the default
    (1, 1/2, 2):  w_i = coef_i / (2 * sqrt(1 - v_i)), matching FlagIRLS.

    ``coef`` carries the per-column objective coefficient (1 for data terms,
    lambda/(p-1) for the pairwise regularizer columns).
    """
    v = jnp.clip(v, 0.0, 1.0 - eps)
    w = jnp.zeros_like(v)
    if beta != 1.0:
        # -(beta-1) * a * (1-v)^{1/a}  has dv-slope  (beta-1)*(1-v)^{1/a-1};
        # for beta<1 this is positive: reward increasing v.
        w = w + (1.0 - beta) * jnp.power(jnp.clip(1.0 - v, eps, 1.0), 1.0 / a - 1.0)
    if alpha != 1.0:
        # alpha part rewards v away from 0 with weight (alpha-1)*v^{1/a-1};
        # a *negative* effective weight would appear for alpha<1 — clip at 0
        # to keep the weighted-PCA step well posed (standard IRLS safeguard).
        w = w + (alpha - 1.0) * jnp.power(jnp.clip(v, eps, 1.0), 1.0 / a - 1.0)
    return coef * jnp.clip(w, 0.0, 1.0 / eps)
