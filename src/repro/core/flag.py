"""Paper-faithful dense Flag Aggregator (FA) — Almasi et al., ICLR 2024.

This is the *reference* implementation: it materializes the gradient matrix
``G in R^{n x p}`` on one device and runs the IRLS of Algorithm 1 with
explicit thin SVDs, exactly as the paper's parameter server does.  It is the
oracle against which the scalable Gram-space implementation
(:mod:`repro.core.gram`) and the distributed runtime (:mod:`repro.dist`) are
tested, and it is what the paper-figure benchmarks run at p<=60 scale.

Objective (paper Eq. 5, data-dependent regularizer):

    min_{Y^T Y = I}  sum_i sqrt(1 - ||Y^T g~_i||^2)
                     + lambda/(p-1) * sum_{i<j} sqrt(1 - ||Y^T d~_ij||^2)

with g~_i the normalized worker gradients and d~_ij the normalized pairwise
differences.  IRLS step: given the current subspace, each sqrt term gets a
majorizer weight  w_c = coef_c / (2 sqrt(1 - v_c))  and the new subspace is
the top-m left-singular subspace of the weight-scaled column stack — i.e. a
weighted PCA (the paper's "few rounds of SVD", Fig. 1).

The aggregated update is  d = (1/p) * Y Y^T G 1  (Algorithm 1, line 6).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import beta_mle

__all__ = ["FlagConfig", "default_m", "flag_aggregate", "flag_subspace"]


@dataclass(frozen=True)
class FlagConfig:
    """Hyper-parameters of the Flag Aggregator.

    Defaults follow the paper's experimental setup: m = ceil((p+1)/2),
    <=5 IRLS iterations, 1e-10 tolerance, Beta(1, 1/2) likelihood smoothed
    with Taylor constant a=2 (i.e. sqrt losses).
    """

    m: int | None = None               # subspace dim; None -> ceil((p+1)/2)
    lam: float = 1.0                   # lambda: pairwise-regularizer strength
    regularizer: Literal["pairwise", "l1", "none"] = "pairwise"
    n_iter: int = 5                    # max IRLS iterations (paper: 5)
    tol: float = 1e-10                 # chordal-distance convergence tol (paper)
    eps: float = 1e-6                  # IRLS weight clip (bounds w <= 1/(2 sqrt(eps)))
    alpha: float = 1.0                 # Beta shape alpha
    beta: float = 0.5                  # Beta shape beta
    a: float = 2.0                     # Taylor smoothing constant (a=2 -> sqrt)
    # Worker-norm handling for the final combine d = (1/p) Y Y^T G 1.
    # The subspace/MLE math is scale-free (it sees normalized columns), but
    # Algorithm 1's update keeps raw norms, so a huge-norm Byzantine gradient
    # that is even partially inside span(Y) gets amplified.  Sec. 2.1 of the
    # paper sanctions reweighing workers "according to noise level"; we expose:
    #   'raw'  — exactly Algorithm 1 (paper-faithful benchmarks)
    #   'clip' — cap each ||g_i|| at the median worker norm (production default)
    #   'unit' — aggregate normalized gradients, restore median norm
    norm_mode: Literal["raw", "clip", "unit"] = "clip"
    # Beyond-paper (FA-N): renormalize the combine weights to sum to 1.
    # Algorithm 1's update d = (1/p) Y Y^T G 1 systematically *shrinks* the
    # step (explained variance < 1 scales every worker down), which slows
    # early training ~2-3x in our CNN benchmarks; renormalizing restores
    # the step scale while keeping the Byzantine-suppressing direction.
    # Off by default for paper-faithfulness; benchmarks report both.
    renormalize: bool = False


def default_m(p: int) -> int:
    """Paper's subspace dimension: m = ceil((p+1)/2)."""
    return int(math.ceil((p + 1) / 2))


def _pair_indices(p: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    iu = jnp.triu_indices(p, k=1)
    return iu[0], iu[1]


def _build_columns(G: jnp.ndarray, cfg: FlagConfig, eps: float):
    """Unit-norm column stack [g~_1..g~_p | d~_ij ...] and objective coefs."""
    n, p = G.shape
    norms = jnp.sqrt(jnp.clip(jnp.sum(G * G, axis=0), eps))
    Gt = G / norms  # normalized worker gradients (columns)
    if cfg.regularizer == "pairwise" and cfg.lam > 0.0 and p > 1:
        ii, jj = _pair_indices(p)
        D = Gt[:, ii] - Gt[:, jj]                       # (n, npairs)
        dn = jnp.sqrt(jnp.clip(jnp.sum(D * D, axis=0), eps))
        Dt = D / dn
        cols = jnp.concatenate([Gt, Dt], axis=1)
        coef = jnp.concatenate(
            [jnp.ones((p,), G.dtype),
             jnp.full((ii.shape[0],), cfg.lam / (p - 1), G.dtype)]
        )
    else:
        cols = Gt
        coef = jnp.ones((p,), G.dtype)
    return cols, coef, norms


def _top_m_left_singular(Mw: jnp.ndarray, m: int) -> jnp.ndarray:
    """Top-m left singular vectors of Mw (n x q), n-major orientation."""
    U, _, _ = jnp.linalg.svd(Mw, full_matrices=False)
    return U[:, :m]


@partial(jax.jit, static_argnames=("cfg",))
def flag_subspace(G: jnp.ndarray, cfg: FlagConfig = FlagConfig()):
    """Run IRLS; return (Y, aux) with Y in R^{n x m}, Y^T Y = I.

    aux: dict with per-worker explained variance ``v`` (the paper's worker
    "value"), the objective value, and iterations actually used.
    """
    n, p = G.shape
    m = cfg.m if cfg.m is not None else default_m(p)
    if not 1 <= m <= min(n, p):
        raise ValueError(f"subspace dim m={m} must be in [1, min(n,p)={min(n, p)}]")
    cols, coef, _ = _build_columns(G, cfg, cfg.eps)

    def explained(Y):
        Z = Y.T @ cols                      # (m, q)
        return jnp.clip(jnp.sum(Z * Z, axis=0), 0.0, 1.0)

    def objective(v):
        return jnp.sum(coef * beta_mle.beta_nll_terms(
            v, alpha=cfg.alpha, beta=cfg.beta, a=cfg.a, eps=cfg.eps))

    # Init: unweighted weighted-PCA (all IRLS weights = coef), i.e. one
    # Flag-Mean step — the paper's "smart initialization" default.
    Y0 = _top_m_left_singular(cols * jnp.sqrt(coef)[None, :], m)

    def cond(state):
        Y, Y_prev, it, done = state
        return jnp.logical_and(it < cfg.n_iter, jnp.logical_not(done))

    def body(state):
        Y, _, it, _ = state
        v = explained(Y)
        w = beta_mle.irls_weights(v, coef, alpha=cfg.alpha, beta=cfg.beta,
                                  a=cfg.a, eps=cfg.eps)
        Y_new = _top_m_left_singular(cols * jnp.sqrt(w)[None, :], m)
        if cfg.regularizer == "l1" and cfg.lam > 0.0:
            # Norm-based regularizer (paper option (1)): approximate
            # proximal step — elementwise soft threshold followed by
            # re-orthonormalization (projection back to the Stiefel set).
            tau = cfg.lam / math.sqrt(n * m)
            Ys = jnp.sign(Y_new) * jnp.maximum(jnp.abs(Y_new) - tau, 0.0)
            Y_new, _ = jnp.linalg.qr(Ys)
        # chordal distance^2 between successive subspaces:
        #   ||Y Y^T - Y' Y'^T||_F^2 = 2(m - ||Y^T Y'||_F^2)
        c2 = 2.0 * (m - jnp.sum((Y.T @ Y_new) ** 2))
        return (Y_new, Y, it + 1, c2 < cfg.tol)

    Y, _, iters, _ = jax.lax.while_loop(
        cond, body, (Y0, jnp.zeros_like(Y0), jnp.asarray(0), jnp.asarray(False)))

    v = explained(Y)
    aux = {
        "explained_variance": v[:p],
        "objective": objective(v),
        "iterations": iters,
        "m": m,
    }
    return Y, aux


@partial(jax.jit, static_argnames=("cfg",))
def flag_aggregate(G: jnp.ndarray, cfg: FlagConfig = FlagConfig()):
    """Aggregate worker gradients: d = (1/p) Y* Y*^T G 1  (Algorithm 1).

    Args:
      G: gradient matrix, shape (n, p) — one column per worker.
    Returns:
      (d, aux): d has shape (n,); aux as in :func:`flag_subspace`.
    """
    _, p = G.shape
    Y, aux = flag_subspace(G, cfg)
    norms = jnp.sqrt(jnp.clip(jnp.sum(G * G, axis=0), cfg.eps))
    nu_eff = effective_norms(norms, cfg.norm_mode)
    g_sum = (G / norms) @ nu_eff            # = G~ @ nu'
    d = (Y @ (Y.T @ g_sum)) / p
    return d, aux


def masked_median_1d(x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Median of ``x[mask]`` with a *dynamic* active count (jit-safe).

    Inactive entries sort to +inf; the two middle order statistics are
    gathered at traced indices, so the active-worker count can change
    step to step without recompiling.
    """
    s = jnp.sort(jnp.where(mask.astype(bool), x, jnp.inf))
    na = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)
    return 0.5 * (s[(na - 1) // 2] + s[na // 2])


def effective_norms(norms: jnp.ndarray, mode: str,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Worker norms used in the final combine (see FlagConfig.norm_mode).

    With ``mask`` (active-worker membership, see repro.dist.membership) the
    median is taken over active workers only and inactive entries are
    zeroed — an inactive worker must contribute nothing to the combine.
    """
    if mode not in ("raw", "clip", "unit"):
        raise ValueError(f"unknown norm_mode {mode!r}")
    if mode == "raw":
        out = norms
    else:
        med = (jnp.median(norms) if mask is None
               else masked_median_1d(norms, mask))
        out = jnp.minimum(norms, med) if mode == "clip" \
            else jnp.full_like(norms, med)
    return out if mask is None else out * mask.astype(norms.dtype)
