"""Gram-space Flag Aggregator — the scalable, TPU-native form of FA.

The paper's Algorithm 1 runs IRLS with an ``n x p`` SVD per iteration at a
parameter server (complexity O(n * N_delta * (sum_i k_i)^2), their Sec. 4
limitation).  On a pod there is no parameter server and n ~ 1e9, so we
re-derive the whole procedure in terms of the p x p Gram matrix K = G^T G.

Two Gram-space solvers coexist (pick via ``solver=``):

``rank_p`` (default)
    Every matrix FA touches has rank <= p, so the IRLS runs entirely in
    p-space: the weighted column Gram collapses to the p x p symmetric
    pencil ``L^T H(u) L`` with ``Kt ~= L L^T`` (Cholesky) and ``H(u) =
    A diag(u) A^T`` assembled in *closed form* — data columns contribute
    ``diag(u[:p])`` and each pairwise column (i, j) is a scaled
    edge-incidence vector, so the pairwise block is a graph Laplacian
    with edge weights ``u_ij / D~^2_ij``.  Cost per IRLS iteration:
    O(p^3) time, O(p^2) memory.  No array with a q-sized dimension is
    ever built (asserted via HLO shape inspection in
    ``tests/test_gram_solvers.py``).

``qspace`` (opt-in oracle)
    The original derivation below, kept as a cross-check: materializes
    the (p, q) mixing matrix ``A`` and the (q, q) column Gram
    ``S = A^T Kt A`` with q = p + p(p-1)/2 and runs a q x q eigh per
    IRLS iteration — O(p^6) time, O(p^4) memory (a 528 x 528 eigh for
    p = 32).

q-space derivation
------------------
Let nu = sqrt(diag K) (worker gradient norms), Kt = K / (nu nu^T) the Gram of
the *normalized* gradients G~.  Every column FA ever decomposes — the data
columns g~_i and the pairwise-regularizer columns d~_ij — is a fixed linear
combination of columns of G~:

    M = G~ A,       A in R^{p x q},  q = p + p(p-1)/2,
    A[:, i] = e_i,  A[:, (i,j)] = (e_i - e_j) / D~_ij,
    D~_ij   = ||g~_i - g~_j|| = sqrt(2 - 2 Kt_ij).

The IRLS weighted-PCA step needs the top-m left-singular subspace Y of
M_w = M diag(sqrt(u)).  With the q x q PSD matrix

    S_w = diag(sqrt(u)) (A^T Kt A) diag(sqrt(u)) = V L V^T   (eigh),

we have Y = M_w V_m L_m^{-1/2} (orthonormal by construction), and every
quantity FA needs is Gram-computable:

  * explained variance of column c:
        v_c = || L_m^{-1/2} V_m^T diag(sqrt(u)) S[:, c] ||^2
  * aggregation update (Algorithm 1, line 6):
        d = (1/p) Y Y^T G 1 = G c,
        c = (1/p) diag(1/nu) W nu,
        W = A diag(sqrt(u)) V_m L_m^{-1} V_m^T diag(sqrt(u)) A^T Kt.

rank-p derivation
-----------------
The weighted covariance C(u) = M_w M_w^T = G~ H(u) G~^T has rank <= p with

    H(u) = A diag(u) A^T
         = diag(u_data) + sum_{i<j} (u_ij / D~^2_ij) (e_i - e_j)(e_i - e_j)^T
         = diag(u_data) + Laplacian(edge weights w_ij = u_ij / D~^2_ij).

Factor Kt + delta*I = L L^T (Cholesky; delta ~ 10 eps absorbs fp32 rounding
and rank-deficient Grams).  B = G~ L^{-T} has (near-)orthonormal columns, so
eigh of the p x p symmetric  M_p = L^T H(u) L = Q Lam Q^T  gives the top-m
subspace  Y = B Q_m  directly — orthonormal Q_m, no pseudo-inverse scaling.
With  Z = Q_m^T L^{-1} Kt  (= Y^T G~, an (m, p) array):

  * explained variances:  v_i = ||Z[:, i]||^2,
        v_ij = ||Z[:, i] - Z[:, j]||^2 / D~^2_ij   (pairwise columns);
  * chordal distance between successive subspaces:
        ||Y^T Y'||_F^2 = ||Q_m^T Q'_m||_F^2  (B cancels);
  * combine weights:
        d = (1/p) Y Y^T G~ nu' = G~ c~,
        c~ = (1/p) L^{-T} Q_m Q_m^T L^{-1} Kt nu'   (triangular solves),
        c  = c~ / nu.

The ``L^{-1} Kt`` form (rather than the algebraically equal ``L^T``) keeps
rank-deficient Grams exact: components of Q_m in the null space of Kt are
annihilated by Kt instead of amplified by L^{-T}, matching the q-space
path's pseudo-inverse treatment.

So the only n-dependent work is forming K (one tall-skinny matmul — a psum
over model shards in the distributed runtime, a Pallas kernel on TPU) and
the final weighted combine G c (a weighted all-reduce); the replicated
per-device solve is O(p^3) per IRLS iteration.

Equivalence with the dense reference (:mod:`repro.core.flag`) and between
the two solvers is asserted in ``tests/test_gram_solvers.py``; the full
derivation with cost accounting lives in ``docs/solver.md``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.analysis.contract import contract
from repro.core import beta_mle
from repro.core.flag import FlagConfig, default_m, effective_norms

__all__ = ["fa_weights_from_gram", "flag_aggregate_gram", "gram_matrix"]

SOLVERS = ("rank_p", "qspace")


def gram_matrix(G: jnp.ndarray) -> jnp.ndarray:
    """K = G^T G in fp32 (the accumulator dtype the Pallas kernel uses)."""
    Gf = G.astype(jnp.float32)
    return Gf.T @ Gf


def _normalized_gram(K: jnp.ndarray, eps: float,
                     mask: jnp.ndarray | None = None):
    """(Kt, nu): unit-diagonal normalized Gram + worker norms.

    With ``mask`` (float (p,), 1 = active) inactive workers become
    *phantom* columns: their rows/cols of Kt are zeroed and their diagonal
    set to 1, i.e. each phantom is a unit vector orthogonal to everything.
    Phantoms then carry zero objective coefficient in both solvers, so the
    active block of every downstream quantity equals the solver run on the
    active submatrix alone (asserted in tests/test_membership.py).
    """
    p = K.shape[0]
    nu = jnp.sqrt(jnp.clip(jnp.diag(K), eps))
    Kt = K / (nu[:, None] * nu[None, :])
    if mask is not None:
        Kt = Kt * (mask[:, None] * mask[None, :])
    # exact unit diagonal (guards eigh/cholesky conditioning; also sets the
    # phantom diagonal):
    Kt = Kt - jnp.diag(jnp.diag(Kt)) + jnp.eye(p, dtype=K.dtype)
    return Kt, nu


def _has_pairs(cfg: FlagConfig, p: int) -> bool:
    return cfg.regularizer == "pairwise" and cfg.lam > 0.0 and p > 1


def _active_count(mask: jnp.ndarray | None, p: int):
    """Dynamic active-worker count (float); the static p when unmasked."""
    if mask is None:
        return jnp.asarray(float(p), jnp.float32)
    return jnp.maximum(jnp.sum(mask), 1.0)


def _mixing(K: jnp.ndarray, cfg: FlagConfig, eps: float,
            mask: jnp.ndarray | None = None):
    """Normalized Gram Kt, mixing matrix A, and per-column coefficients.

    With ``mask``, inactive workers' data columns and every pair touching
    an inactive worker get coefficient 0 (their IRLS weight is then exactly
    0, so they never enter the weighted column Gram), and the pairwise
    coefficient becomes lambda / (W_active - 1) — a traced scalar, so
    membership changes never trigger a recompile.
    """
    p = K.shape[0]
    Kt, nu = _normalized_gram(K, eps, mask)
    eye = jnp.eye(p, dtype=K.dtype)
    wa = _active_count(mask, p)
    data_coef = (jnp.ones((p,), K.dtype) if mask is None
                 else mask.astype(K.dtype))
    if _has_pairs(cfg, p):
        ii, jj = jnp.triu_indices(p, k=1)
        d2 = jnp.clip(2.0 - 2.0 * Kt[ii, jj], 0.0)
        inv_d = jnp.where(d2 > 1e-12, jax.lax.rsqrt(jnp.maximum(d2, 1e-12)), 0.0)
        Apairs = (eye[:, ii] - eye[:, jj]) * inv_d[None, :]   # (p, npairs)
        A = jnp.concatenate([eye, Apairs], axis=1)
        pair_coef = cfg.lam / jnp.maximum(wa - 1.0, 1.0)
        pair_valid = (jnp.ones((ii.shape[0],), K.dtype) if mask is None
                      else mask[ii] * mask[jj])
        coef = jnp.concatenate([data_coef, pair_coef * pair_valid])
    else:
        A = eye
        coef = data_coef
    return Kt, nu, A, coef


def _safe_inv(lam: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Pseudo-inverse of eigenvalues (rank-deficient Grams are expected)."""
    return jnp.where(lam > eps, 1.0 / jnp.maximum(lam, eps), 0.0)


# ---------------------------------------------------------------------------
# q-space solver (the original derivation, retained as the cross-check
# oracle: O(p^6)/iteration — see module docstring)
# ---------------------------------------------------------------------------

def _fa_weights_qspace(K: jnp.ndarray, cfg: FlagConfig,
                       mask: jnp.ndarray | None = None):
    p = K.shape[0]
    m = cfg.m if cfg.m is not None else default_m(p)
    eps = cfg.eps
    Kt, nu, A, coef = _mixing(K, cfg, eps, mask)
    S = A.T @ Kt @ A                       # (q, q), Gram of unit columns

    def eig_top_m(u):
        su = jnp.sqrt(u)
        Sw = S * (su[:, None] * su[None, :])
        lam, V = jnp.linalg.eigh(Sw)       # ascending
        return lam[-m:], V[:, -m:], su

    def explained(lam_m, Vm, su):
        # v_c = || L^{-1/2} Vm^T diag(su) S[:,c] ||^2
        Z = (Vm * jnp.sqrt(_safe_inv(lam_m, eps))[None, :]).T @ (su[:, None] * S)
        return jnp.clip(jnp.sum(Z * Z, axis=0), 0.0, 1.0)

    u0 = coef
    lam0, V0, su0 = eig_top_m(u0)

    def cond(state):
        it, done, *_ = state
        return jnp.logical_and(it < cfg.n_iter, jnp.logical_not(done))

    def body(state):
        it, _, u, lam_m, Vm, su = state
        v = explained(lam_m, Vm, su)
        u_new = beta_mle.irls_weights(v, coef, alpha=cfg.alpha, beta=cfg.beta,
                                      a=cfg.a, eps=eps)
        lam_n, Vn, su_n = eig_top_m(u_new)
        # chordal distance between successive subspaces, in Gram space:
        #   Y^T Y' = L^{-1/2} V^T diag(su) S diag(su') V' L'^{-1/2}
        C = (Vm * jnp.sqrt(_safe_inv(lam_m, eps))[None, :]).T \
            @ (su[:, None] * S * su_n[None, :]) \
            @ (Vn * jnp.sqrt(_safe_inv(lam_n, eps))[None, :])
        c2 = 2.0 * (m - jnp.sum(C * C))
        return (it + 1, c2 < cfg.tol, u_new, lam_n, Vn, su_n)

    it, _, u, lam_m, Vm, su = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), jnp.asarray(False), u0, lam0, V0, su0))

    # Final combine:  W = A diag(su) Vm L^{-1} Vm^T diag(su) A^T Kt
    B = A * su[None, :]                    # (p, q) = A diag(su)
    P = (Vm * _safe_inv(lam_m, eps)[None, :]) @ Vm.T   # (q, q)
    W = B @ P @ (B.T @ Kt)                 # (p, p)
    nu_eff = effective_norms(nu, cfg.norm_mode, mask)
    c = (W @ nu_eff) / (nu * _active_count(mask, p))
    if mask is not None:
        c = c * mask
    if cfg.renormalize:  # FA-N (see FlagConfig)
        c = c / jnp.maximum(jnp.abs(jnp.sum(c)), 1e-6)

    v = explained(lam_m, Vm, su)
    aux = {
        "explained_variance": v[:p],
        "objective": jnp.sum(coef * beta_mle.beta_nll_terms(
            v, alpha=cfg.alpha, beta=cfg.beta, a=cfg.a, eps=eps)),
        "iterations": it,
        "weights": c,
        "m": m,
    }
    return c, aux


# ---------------------------------------------------------------------------
# rank-p solver (default: O(p^3)/iteration, O(p^2) memory — see module
# docstring for the derivation)
# ---------------------------------------------------------------------------

def _fa_weights_rank_p(K: jnp.ndarray, cfg: FlagConfig,
                       mask: jnp.ndarray | None = None):
    p = K.shape[0]
    m = cfg.m if cfg.m is not None else default_m(p)
    if m > p:
        raise ValueError(
            f"rank-p solver needs subspace dim m={m} <= p={p} (every FA "
            "subspace lies in span(G)); use solver='qspace' only as a "
            "debugging oracle")
    eps = cfg.eps
    Kt, nu = _normalized_gram(K, eps, mask)
    has_pairs = _has_pairs(cfg, p)
    wa = _active_count(mask, p)
    # Cholesky jitter (see below) — also enters the pair normalization.
    delta = 10.0 * eps

    # Pairwise-column geometry, (p, p) symmetric, zero diagonal:
    #   D~^2_ij = ||g~_i - g~_j||^2 = 2 - 2 Kt_ij;  degenerate pairs
    #   (duplicated workers, D~ -> 0) get inv_d2 = 0 — their q-space column
    #   is the zero vector, contributing nothing to H(u).  The edge is
    #   normalized in the *jittered* metric, 1/(D~^2 + 2 delta), because
    #   ||L^T (e_i - e_j)||^2 = D~^2_ij + 2 delta: with the bare 1/D~^2 a
    #   near-duplicate pair (D~^2 ~ fp32 rounding ~ delta) would see its
    #   pencil eigenvalue inflated by (D~^2 + 2 delta)/D~^2 >> 1 and drag
    #   a spurious difference direction into the top-m subspace.  For
    #   separated pairs the correction is O(delta) — below fp32 noise.
    if has_pairs:
        d2 = jnp.clip(2.0 - 2.0 * Kt, 0.0)
        inv_d2 = jnp.where(d2 > 1e-12, 1.0 / (d2 + 2.0 * delta), 0.0)
        inv_d2 = inv_d2 - jnp.diag(jnp.diag(inv_d2))
        coef_pair = (cfg.lam / jnp.maximum(wa - 1.0, 1.0)).astype(K.dtype)
        pair_mask = jnp.triu(jnp.ones((p, p), K.dtype), k=1)
    else:
        inv_d2 = jnp.zeros((p, p), K.dtype)
        coef_pair = jnp.asarray(0.0, K.dtype)
        pair_mask = jnp.zeros((p, p), K.dtype)
    coef_data = jnp.ones((p,), K.dtype)
    if mask is not None:
        # Membership masking: inactive workers' data columns carry zero
        # coefficient and every pair touching one is dropped from the edge
        # set — the masked Kt already made their d2 degenerate (phantoms
        # are mutually orthogonal, d2 = 2), so inv_d2 must be zeroed
        # explicitly, not relied on to vanish.
        mm = mask[:, None] * mask[None, :]
        inv_d2 = inv_d2 * mm
        pair_mask = pair_mask * mm
        coef_data = mask.astype(K.dtype)

    # Symmetrizer: Kt + delta I = L L^T.  The jitter bounds the Cholesky
    # away from fp32 rounding (Kt is PSD up to ~p*ulp) and gives
    # rank-deficient Grams a well-defined factor; the combine/variance
    # formulas below use L^{-1} Kt so null-space directions stay exact.
    L = jnp.linalg.cholesky(Kt + delta * jnp.eye(p, dtype=K.dtype))
    LinvK = solve_triangular(L, Kt, lower=True)        # (p, p) = L^{-1} Kt

    def assemble_h(u_data, u_pairs):
        """H(u) = diag(u_data) + Laplacian(edge weights u_ij / D~^2_ij)."""
        Ew = u_pairs * inv_d2                          # (p, p), zero diag
        return jnp.diag(u_data + jnp.sum(Ew, axis=1)) - Ew

    def eig_top_m(u_data, u_pairs):
        Mp = L.T @ (assemble_h(u_data, u_pairs) @ L)   # (p, p)
        _, Q = jnp.linalg.eigh(0.5 * (Mp + Mp.T))      # ascending
        return Q[:, -m:]

    def explained(Qm):
        """(v_data (p,), v_pairs (p, p)) from Z = Qm^T L^{-1} Kt = Y^T G~."""
        Z = Qm.T @ LinvK                               # (m, p)
        v_data = jnp.clip(jnp.sum(Z * Z, axis=0), 0.0, 1.0)
        # ||Z_i - Z_j||^2 = v_i + v_j - 2 (Z^T Z)_ij, then / D~^2_ij
        ZtZ = Z.T @ Z
        pd2 = jnp.clip(v_data[:, None] + v_data[None, :] - 2.0 * ZtZ, 0.0)
        v_pairs = jnp.clip(pd2 * inv_d2, 0.0, 1.0)
        return v_data, v_pairs

    def irls(v_data, v_pairs):
        u_data = beta_mle.irls_weights(v_data, coef_data, alpha=cfg.alpha,
                                       beta=cfg.beta, a=cfg.a, eps=eps)
        u_pairs = beta_mle.irls_weights(v_pairs, coef_pair, alpha=cfg.alpha,
                                        beta=cfg.beta, a=cfg.a, eps=eps)
        return u_data, u_pairs

    # Init: u = coef (one Flag-Mean step), exactly the q-space init.
    Q0 = eig_top_m(coef_data, jnp.full((p, p), coef_pair, K.dtype))

    def cond(state):
        it, done, _ = state
        return jnp.logical_and(it < cfg.n_iter, jnp.logical_not(done))

    def body(state):
        it, _, Qm = state
        u_data, u_pairs = irls(*explained(Qm))
        Qn = eig_top_m(u_data, u_pairs)
        # chordal distance^2 between successive subspaces: B cancels, so
        #   ||Y^T Y'||_F^2 = ||Qm^T Qn||_F^2
        c2 = 2.0 * (m - jnp.sum((Qm.T @ Qn) ** 2))
        return (it + 1, c2 < cfg.tol, Qn)

    it, _, Qm = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), jnp.asarray(False), Q0))

    # Final combine:  c~ = (1/W_a) L^{-T} Qm Qm^T L^{-1} Kt nu',  c = c~/nu.
    nu_eff = effective_norms(nu, cfg.norm_mode, mask)
    s = solve_triangular(L, Kt @ nu_eff, lower=True)
    ct = solve_triangular(L, Qm @ (Qm.T @ s), lower=True, trans=1)
    c = ct / (nu * wa)
    if mask is not None:
        c = c * mask
    if cfg.renormalize:  # FA-N (see FlagConfig)
        c = c / jnp.maximum(jnp.abs(jnp.sum(c)), 1e-6)

    v_data, v_pairs = explained(Qm)
    nll = partial(beta_mle.beta_nll_terms, alpha=cfg.alpha, beta=cfg.beta,
                  a=cfg.a, eps=eps)
    objective = jnp.sum(coef_data * nll(v_data))
    if has_pairs:
        objective = objective + coef_pair * jnp.sum(pair_mask * nll(v_pairs))
    aux = {
        "explained_variance": v_data,
        "objective": objective,
        "iterations": it,
        "weights": c,
        "m": m,
    }
    return c, aux


# The rank-p contract: with the default solver no traced array carries a
# dimension beyond p = K.shape[0]; the qspace oracle waives the bound (it
# materializes q = p + p(p-1)/2 by design).  Checked under
# REPRO_CONTRACTS=1; tests/test_gram_solvers.py pins both directions.
@contract(max_dim=lambda K, *a, **kw: (
    int(K.shape[0]) if kw.get("solver", "rank_p") == "rank_p" else None),
    no_host_transfers=True, mask_traced=True)
@partial(jax.jit, static_argnames=("cfg", "solver"))
def fa_weights_from_gram(K: jnp.ndarray, cfg: FlagConfig = FlagConfig(), *,
                         solver: str = "rank_p",
                         mask: jnp.ndarray | None = None):
    """FA combination weights c from the Gram matrix only.

    Args:
      K: (p, p) Gram of raw worker gradients, K_ij = g_i . g_j  (fp32).
      cfg: FA hyper-parameters (static).
      solver: ``'rank_p'`` (default — p x p eigh per IRLS iteration, no
        q-sized intermediate) or ``'qspace'`` (the original q x q
        derivation, q = p + p(p-1)/2, retained as a cross-check oracle).
      mask: optional (p,) active-worker membership (bool or 0/1 float, a
        *traced* value — membership changes never recompile).  Inactive
        workers become zero-coefficient phantom columns: the solve on the
        active block equals the solver run on the active submatrix (exact
        whenever m <= W_active; with fewer active workers than subspace
        dims the extra directions are degenerate but the weights stay
        finite and masked), and c is zero at inactive workers.
    Returns:
      (c, aux): c (p,) with  d = G @ c  reproducing Algorithm 1's update;
      aux holds per-worker explained variance, IRLS iterations, objective.
    """
    K = K.astype(jnp.float32)
    if mask is not None:
        mask = mask.astype(jnp.float32)
    if solver == "rank_p":
        return _fa_weights_rank_p(K, cfg, mask)
    if solver == "qspace":
        return _fa_weights_qspace(K, cfg, mask)
    raise ValueError(f"unknown solver {solver!r}; have {SOLVERS}")


@partial(jax.jit, static_argnames=("cfg", "solver"))
def flag_aggregate_gram(G: jnp.ndarray, cfg: FlagConfig = FlagConfig(), *,
                        solver: str = "rank_p"):
    """Single-host convenience: d = G @ fa_weights_from_gram(G^T G)."""
    c, aux = fa_weights_from_gram(gram_matrix(G), cfg, solver=solver)
    return G @ c.astype(G.dtype), aux
