"""Gram-space Flag Aggregator — the scalable, TPU-native form of FA.

The paper's Algorithm 1 runs IRLS with an ``n x p`` SVD per iteration at a
parameter server (complexity O(n * N_delta * (sum_i k_i)^2), their Sec. 4
limitation).  On a pod there is no parameter server and n ~ 1e9, so we
re-derive the whole procedure in terms of the p x p Gram matrix K = G^T G.

Derivation
----------
Let nu = sqrt(diag K) (worker gradient norms), Kt = K / (nu nu^T) the Gram of
the *normalized* gradients G~.  Every column FA ever decomposes — the data
columns g~_i and the pairwise-regularizer columns d~_ij — is a fixed linear
combination of columns of G~:

    M = G~ A,       A in R^{p x q},  q = p + p(p-1)/2,
    A[:, i] = e_i,  A[:, (i,j)] = (e_i - e_j) / D~_ij,
    D~_ij   = ||g~_i - g~_j|| = sqrt(2 - 2 Kt_ij).

The IRLS weighted-PCA step needs the top-m left-singular subspace Y of
M_w = M diag(sqrt(u)).  With the q x q PSD matrix

    S_w = diag(sqrt(u)) (A^T Kt A) diag(sqrt(u)) = V L V^T   (eigh),

we have Y = M_w V_m L_m^{-1/2} (orthonormal by construction), and every
quantity FA needs is Gram-computable:

  * explained variance of column c:
        v_c = || L_m^{-1/2} V_m^T diag(sqrt(u)) S[:, c] ||^2
  * aggregation update (Algorithm 1, line 6):
        d = (1/p) Y Y^T G 1 = G c,
        c = (1/p) diag(1/nu) W nu,
        W = A diag(sqrt(u)) V_m L_m^{-1} V_m^T diag(sqrt(u)) A^T Kt.

So the only n-dependent work is forming K (one tall-skinny matmul — a psum
over model shards in the distributed runtime, a Pallas kernel on TPU) and
the final weighted combine G c (a weighted all-reduce).  The q^3 eigh is
replicated on every device: q <= 528 even for p = 32 workers.

Equivalence with the dense reference (:mod:`repro.core.flag`) is asserted to
~1e-5 in ``tests/test_gram.py``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import beta_mle
from repro.core.flag import FlagConfig, default_m, effective_norms

__all__ = ["fa_weights_from_gram", "flag_aggregate_gram", "gram_matrix"]


def gram_matrix(G: jnp.ndarray) -> jnp.ndarray:
    """K = G^T G in fp32 (the accumulator dtype the Pallas kernel uses)."""
    Gf = G.astype(jnp.float32)
    return Gf.T @ Gf


def _mixing(K: jnp.ndarray, cfg: FlagConfig, eps: float):
    """Normalized Gram Kt, mixing matrix A, and per-column coefficients."""
    p = K.shape[0]
    nu = jnp.sqrt(jnp.clip(jnp.diag(K), eps))
    Kt = K / (nu[:, None] * nu[None, :])
    # exact unit diagonal (guards eigh conditioning):
    Kt = Kt - jnp.diag(jnp.diag(Kt)) + jnp.eye(p, dtype=K.dtype)
    eye = jnp.eye(p, dtype=K.dtype)
    if cfg.regularizer == "pairwise" and cfg.lam > 0.0 and p > 1:
        ii, jj = jnp.triu_indices(p, k=1)
        d2 = jnp.clip(2.0 - 2.0 * Kt[ii, jj], 0.0)
        inv_d = jnp.where(d2 > 1e-12, jax.lax.rsqrt(jnp.maximum(d2, 1e-12)), 0.0)
        Apairs = (eye[:, ii] - eye[:, jj]) * inv_d[None, :]   # (p, npairs)
        A = jnp.concatenate([eye, Apairs], axis=1)
        coef = jnp.concatenate(
            [jnp.ones((p,), K.dtype),
             jnp.full((ii.shape[0],), cfg.lam / (p - 1), K.dtype)])
    else:
        A = eye
        coef = jnp.ones((p,), K.dtype)
    return Kt, nu, A, coef


def _safe_inv(lam: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Pseudo-inverse of eigenvalues (rank-deficient Grams are expected)."""
    return jnp.where(lam > eps, 1.0 / jnp.maximum(lam, eps), 0.0)


@partial(jax.jit, static_argnames=("cfg",))
def fa_weights_from_gram(K: jnp.ndarray, cfg: FlagConfig = FlagConfig()):
    """FA combination weights c from the Gram matrix only.

    Args:
      K: (p, p) Gram of raw worker gradients, K_ij = g_i . g_j  (fp32).
    Returns:
      (c, aux): c (p,) with  d = G @ c  reproducing Algorithm 1's update;
      aux holds per-worker explained variance, IRLS iterations, objective.
    """
    K = K.astype(jnp.float32)
    p = K.shape[0]
    m = cfg.m if cfg.m is not None else default_m(p)
    eps = cfg.eps
    Kt, nu, A, coef = _mixing(K, cfg, eps)
    S = A.T @ Kt @ A                       # (q, q), Gram of unit columns
    q = S.shape[0]

    def eig_top_m(u):
        su = jnp.sqrt(u)
        Sw = S * (su[:, None] * su[None, :])
        lam, V = jnp.linalg.eigh(Sw)       # ascending
        return lam[-m:], V[:, -m:], su

    def explained(lam_m, Vm, su):
        # v_c = || L^{-1/2} Vm^T diag(su) S[:,c] ||^2
        Z = (Vm * jnp.sqrt(_safe_inv(lam_m, eps))[None, :]).T @ (su[:, None] * S)
        return jnp.clip(jnp.sum(Z * Z, axis=0), 0.0, 1.0)

    u0 = coef
    lam0, V0, su0 = eig_top_m(u0)

    def cond(state):
        it, done, *_ = state
        return jnp.logical_and(it < cfg.n_iter, jnp.logical_not(done))

    def body(state):
        it, _, u, lam_m, Vm, su = state
        v = explained(lam_m, Vm, su)
        u_new = beta_mle.irls_weights(v, coef, alpha=cfg.alpha, beta=cfg.beta,
                                      a=cfg.a, eps=eps)
        lam_n, Vn, su_n = eig_top_m(u_new)
        # chordal distance between successive subspaces, in Gram space:
        #   Y^T Y' = L^{-1/2} V^T diag(su) S diag(su') V' L'^{-1/2}
        C = (Vm * jnp.sqrt(_safe_inv(lam_m, eps))[None, :]).T \
            @ (su[:, None] * S * su_n[None, :]) \
            @ (Vn * jnp.sqrt(_safe_inv(lam_n, eps))[None, :])
        c2 = 2.0 * (m - jnp.sum(C * C))
        return (it + 1, c2 < cfg.tol, u_new, lam_n, Vn, su_n)

    it, _, u, lam_m, Vm, su = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), jnp.asarray(False), u0, lam0, V0, su0))

    # Final combine:  W = A diag(su) Vm L^{-1} Vm^T diag(su) A^T Kt
    B = A * su[None, :]                    # (p, q) = A diag(su)
    P = (Vm * _safe_inv(lam_m, eps)[None, :]) @ Vm.T   # (q, q)
    W = B @ P @ (B.T @ Kt)                 # (p, p)
    nu_eff = effective_norms(nu, cfg.norm_mode)
    c = (W @ nu_eff) / (nu * p)
    if cfg.renormalize:  # FA-N (see FlagConfig)
        c = c / jnp.maximum(jnp.abs(jnp.sum(c)), 1e-6)

    v = explained(lam_m, Vm, su)
    aux = {
        "explained_variance": v[:p],
        "objective": jnp.sum(coef * beta_mle.beta_nll_terms(
            v, alpha=cfg.alpha, beta=cfg.beta, a=cfg.a, eps=eps)),
        "iterations": it,
        "weights": c,
        "m": m,
    }
    return c, aux


@partial(jax.jit, static_argnames=("cfg",))
def flag_aggregate_gram(G: jnp.ndarray, cfg: FlagConfig = FlagConfig()):
    """Single-host convenience: d = G @ fa_weights_from_gram(G^T G)."""
    c, aux = fa_weights_from_gram(gram_matrix(G), cfg)
    return G @ c.astype(G.dtype), aux
