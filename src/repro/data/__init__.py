"""Data substrate: deterministic synthetic tasks, nonlinear augmentations,
and the sharded per-worker batch pipeline."""

from repro.data import augment, pipeline
from repro.data.synthetic import (SyntheticImages, SyntheticLM,
                                  make_image_task, make_lm_task)

__all__ = ["SyntheticImages", "SyntheticLM", "make_image_task",
           "make_lm_task", "augment", "pipeline"]
