"""Nonlinear data augmentations from the paper (Sec. 3.1 "Evaluating
resilience against nonlinear data augmentation").

The paper induces *dependent* (non-i.i.d.) Byzantine-style noise by
augmenting training images with numerically-solved nonlinear systems:

* **Lotka-Volterra**:  (x, y) -> (alpha x - beta x y,  delta x y - gamma y)
  with (alpha, beta, gamma, delta) = (2/3, 4/3, -1, -1); the paper
  integrates with SciPy's LSODA.  We integrate the same vector field with
  fixed-step RK4 in pure JAX (deterministic, jit/vmap-safe, offline) on
  channel pairs of the image treated as the (x, y) state.
* **Arnold's Cat Map**:  (x, y) -> ((2x + y) mod N, (x + y) mod N) on pixel
  coordinates — an area-preserving chaotic shuffle, plus the paper's
  *smooth* approximation with the sigmoid-approximated mod (their m = 0.95),
  implemented with bilinear resampling.

Plus the paper's "varying level of Gaussian noise" added on top.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

LV_PARAMS = (2.0 / 3.0, 4.0 / 3.0, -1.0, -1.0)   # alpha, beta, gamma, delta


def _lv_field(state, params=LV_PARAMS):
    alpha, beta, gamma, delta = params
    x, y = state
    return (alpha * x - beta * x * y, delta * x * y - gamma * y)


def rk4(field, state, dt: float, steps: int):
    """Fixed-step RK4 integrator for a pytree state."""
    def one(state, _):
        k1 = field(state)
        k2 = field(jax.tree.map(lambda s, k: s + 0.5 * dt * k, state, k1))
        k3 = field(jax.tree.map(lambda s, k: s + 0.5 * dt * k, state, k2))
        k4 = field(jax.tree.map(lambda s, k: s + dt * k, state, k3))
        new = jax.tree.map(
            lambda s, a, b, c, d: s + dt / 6.0 * (a + 2 * b + 2 * c + d),
            state, k1, k2, k3, k4)
        return new, None
    out, _ = jax.lax.scan(one, state, None, length=steps)
    return out


@functools.partial(jax.jit, static_argnames=("steps",))
def lotka_volterra(images: jnp.ndarray, *, t: float = 1.0, steps: int = 16):
    """images: (..., H, W, ch) in [0,1].  Channel pairs (0,1) evolve under
    the LV flow; odd trailing channel left unchanged."""
    ch = images.shape[-1]
    npair = ch // 2
    x = images[..., 0:2 * npair:2] + 0.5      # keep state away from 0
    y = images[..., 1:2 * npair:2] + 0.5
    xs, ys = rk4(_lv_field, (x, y), t / steps, steps)
    out = jnp.stack([xs - 0.5, ys - 0.5], axis=-1)
    out = out.reshape(*images.shape[:-1], 2 * npair)
    if ch % 2:
        out = jnp.concatenate([out, images[..., -1:]], axis=-1)
    return jnp.clip(out, 0.0, 1.0)


@functools.partial(jax.jit, static_argnames=("iterations",))
def cat_map(images: jnp.ndarray, *, iterations: int = 1):
    """Exact Arnold cat map on pixel coordinates (square images)."""
    H, W = images.shape[-3], images.shape[-2]
    assert H == W, "cat map needs square images"
    yy, xx = jnp.mgrid[0:H, 0:W]
    for _ in range(iterations):
        xx, yy = (2 * xx + yy) % W, (xx + yy) % H
    return images[..., yy, xx, :]


def _bilinear(img, xf, yf):
    """img: (H, W, ch); xf/yf: (H, W) float sample coords."""
    H, W = img.shape[0], img.shape[1]
    x0 = jnp.clip(jnp.floor(xf).astype(jnp.int32), 0, W - 1)
    y0 = jnp.clip(jnp.floor(yf).astype(jnp.int32), 0, H - 1)
    x1, y1 = jnp.minimum(x0 + 1, W - 1), jnp.minimum(y0 + 1, H - 1)
    wx = (xf - x0)[..., None]
    wy = (yf - y0)[..., None]
    return ((1 - wy) * ((1 - wx) * img[y0, x0] + wx * img[y0, x1])
            + wy * ((1 - wx) * img[y1, x0] + wx * img[y1, x1]))


@jax.jit
def smooth_cat_map(images: jnp.ndarray, *, m: float = 0.95):
    """Paper's smooth approximation: mod replaced by the sigmoid form
    1/(1 + exp(-m log(a)))."""
    H, W = images.shape[-3], images.shape[-2]
    yy, xx = jnp.mgrid[0:H, 0:W]
    a1 = (2 * xx + yy).astype(jnp.float32) / W + 1e-6
    a2 = (xx + yy).astype(jnp.float32) / H + 1e-6
    sx = W * jax.nn.sigmoid(m * jnp.log(a1))
    sy = H * jax.nn.sigmoid(m * jnp.log(a2))
    fn = lambda img: _bilinear(img, sx, sy)
    for _ in range(images.ndim - 3):
        fn = jax.vmap(fn)
    return fn(images)


def augment_batch(key, images, *, scheme: str, gaussian_sigma: float = 0.05):
    """Apply ``scheme`` + Gaussian noise (paper's combined setting)."""
    if scheme == "lotka_volterra":
        images = lotka_volterra(images)
    elif scheme == "cat_map":
        images = cat_map(images)
    elif scheme == "smooth_cat_map":
        images = smooth_cat_map(images)
    elif scheme != "none":
        raise ValueError(f"unknown augmentation {scheme!r}")
    if gaussian_sigma:
        images = images + gaussian_sigma * jax.random.normal(key, images.shape)
    return jnp.clip(images, 0.0, 1.0)
