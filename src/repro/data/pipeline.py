"""Per-worker batch pipeline.

Produces worker-major batches with a leading worker axis — the layout the
distributed train step consumes (worker axis shards over (pod, data)).
Each worker draws from an independent, deterministic key stream; augmented
workers apply the paper's nonlinear schemes to their share of samples.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.data import augment
from repro.data.synthetic import SyntheticImages, SyntheticLM


@dataclass
class WorkerDataConfig:
    workers: int
    per_worker_batch: int
    augment_workers: int = 0          # first k workers augment their data
    augment_scheme: str = "none"
    gaussian_sigma: float = 0.0


def image_worker_batches(task: SyntheticImages, cfg: WorkerDataConfig,
                         step: int, seed: int = 0):
    """-> (images (W, B, H, W, ch), labels (W, B))."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    keys = jax.random.split(base, cfg.workers)

    def one(i, key):
        kx, ka = jax.random.split(key)
        x, y = task.sample(kx, cfg.per_worker_batch)
        if cfg.augment_scheme != "none" and cfg.augment_workers > 0:
            xa = augment.augment_batch(ka, x, scheme=cfg.augment_scheme,
                                       gaussian_sigma=cfg.gaussian_sigma)
            x = jnp.where(i < cfg.augment_workers, xa, x)
        return x, y

    xs, ys = zip(*[one(i, keys[i]) for i in range(cfg.workers)])
    return jnp.stack(xs), jnp.stack(ys)


def lm_worker_batches(task: SyntheticLM, cfg: WorkerDataConfig, step: int,
                      seq_len: int, seed: int = 0):
    """-> {tokens: (W, B, S), labels: (W, B, S)} worker-major LM batches."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    keys = jax.random.split(base, cfg.workers)
    batches = [task.batch(k, cfg.per_worker_batch, seq_len) for k in keys]
    return {k: jnp.stack([b[k] for b in batches]) for k in batches[0]}
