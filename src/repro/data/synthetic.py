"""Deterministic synthetic datasets (the container is offline — no CIFAR).

Two task families:

* **SyntheticImages** — a CIFAR-10-shaped stand-in for the paper's accuracy
  experiments: C class templates built from low-frequency Fourier patterns
  plus per-sample Gaussian pixel noise.  Relative aggregator orderings
  under Byzantine attacks reproduce on it (EXPERIMENTS.md §Repro caveat).
  Images are (H, W, ch) in [0, 1], so the paper's nonlinear augmentations
  (Lotka-Volterra / Arnold's Cat Map, data/augment.py) apply directly.
* **SyntheticLM** — a deterministic token stream with n-gram structure for
  the language-model architectures' end-to-end training driver.

Everything derives from a single integer seed via ``jax.random`` /
``numpy.random.Generator(PCG64(seed))`` — byte-for-byte reproducible, no
files.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticImages:
    num_classes: int = 10
    height: int = 32
    width: int = 32
    channels: int = 3
    noise: float = 0.25
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        yy, xx = np.mgrid[0:self.height, 0:self.width].astype(np.float32)
        yy, xx = yy / self.height, xx / self.width
        templates = []
        for _ in range(self.num_classes):
            t = np.zeros((self.height, self.width, self.channels), np.float32)
            for c in range(self.channels):
                for _ in range(3):  # 3 low-frequency components
                    fy, fx = rng.integers(1, 4, size=2)
                    ph = rng.uniform(0, 2 * np.pi, size=2)
                    t[:, :, c] += rng.uniform(0.3, 1.0) * (
                        np.sin(2 * np.pi * fy * yy + ph[0])
                        * np.sin(2 * np.pi * fx * xx + ph[1]))
            t = (t - t.min()) / max(t.max() - t.min(), 1e-6)
            templates.append(t)
        self.templates = jnp.asarray(np.stack(templates))

    def sample(self, key, batch: int):
        """-> (images (B,H,W,ch) in [0,1], labels (B,))."""
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (batch,), 0, self.num_classes)
        x = self.templates[y]
        x = x + self.noise * jax.random.normal(k2, x.shape)
        return jnp.clip(x, 0.0, 1.0), y

    def test_set(self, n: int = 2048, seed: int = 999):
        return self.sample(jax.random.PRNGKey(seed), n)


@dataclass
class SyntheticLM:
    """Markov-chain token stream: learnable structure, deterministic."""
    vocab_size: int = 512
    order: int = 2
    seed: int = 0
    branch: int = 4   # successors per context

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # hash-based successor table: ctx -> branch successor tokens
        self._a = rng.integers(1, 2**31 - 1)
        self._b = rng.integers(1, 2**31 - 1)

    def _succ(self, ctx):
        h = (ctx * self._a + self._b) % (2**31 - 1)
        return (h[..., None] * (jnp.arange(self.branch) + 1)) % self.vocab_size

    def sample(self, key, batch: int, seq_len: int):
        """-> tokens (B, S+1) int32; use [:, :-1] as inputs, [:, 1:] labels."""
        k1, k2 = jax.random.split(key)
        start = jax.random.randint(k1, (batch,), 0, self.vocab_size)
        picks = jax.random.randint(k2, (batch, seq_len), 0, self.branch)

        def step(tok, pick):
            succ = self._succ(tok)
            nxt = jnp.take_along_axis(succ, pick[:, None], axis=-1)[:, 0]
            return nxt, tok

        last, toks = jax.lax.scan(step, start, picks.T)
        toks = jnp.concatenate([toks.T, last[:, None]], axis=1)
        return toks.astype(jnp.int32)

    def batch(self, key, batch: int, seq_len: int):
        toks = self.sample(key, batch, seq_len)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_image_task(seed: int = 0, **kw) -> SyntheticImages:
    return SyntheticImages(seed=seed, **kw)


def make_lm_task(vocab_size: int, seed: int = 0, **kw) -> SyntheticLM:
    return SyntheticLM(vocab_size=vocab_size, seed=seed, **kw)
