"""Distribution layer: logical-axis sharding, worker-major tree aggregation,
and the jit-able train / serve steps.

Modules (imported in dependency order — ``sharding`` has no repro deps, the
model substrate imports it, and ``train_step``/``serve_step`` sit on top of
the models):

  sharding     — ``shard`` logical-axis constraints + ``use_sharding`` context
  membership   — elastic worker membership: ``FaultSchedule`` outage events
                 (crash / leave+rejoin / churn / straggle, mirroring the
                 attacks registry) -> in-graph (W,) active mask + staleness
                 counters, a pure function of the step index
  aggregation  — ``aggregate_tree``: Byzantine-robust pytree aggregation that
                 routes FA (and every Gram-computable baseline) through the
                 p x p Gram matrix, never materializing the flat (W, n) stack;
                 ``compressed_aggregate`` wraps it with the ``repro.comm``
                 worker->server codecs (sketch payloads feed the Gram path);
                 both take a membership ``mask`` so every rule operates on a
                 dynamic worker subset without recompiling, and a
                 ``sharded=`` mesh to run the whole thing mesh-native
  sharded      — the mesh-sharded dataflow behind ``sharded=``: coordinate
                 shards on every device, partial-Gram ``psum``, replicated
                 p x p weight solve, shard-local combine — the full (W, n)
                 stack never exists on any single device
  train_step   — vmapped per-worker grads -> attack injection -> compression
                 -> aggregation -> optimizer update, as one pure function
                 (EF memory threads through as an explicit carry; a
                 ``TrainConfig.faults`` schedule masks the round in-graph;
                 ``TrainConfig.sharded_agg`` makes the gradient stack
                 coordinate-sharded by construction)
  serve_step   — one-token greedy decode step + the batched decode loop
"""

from repro.dist import (aggregation, membership, serve_step, sharded,
                        sharding, train_step)

__all__ = ["sharding", "membership", "aggregation", "sharded", "train_step",
           "serve_step"]
