"""Worker-major pytree aggregation — the distributed form of every rule.

The distributed runtime holds gradients as a *pytree* whose leaves carry a
leading worker axis ``(W, ...)`` (the output of ``vmap(grad)``).  The naive
way to aggregate is to flatten everything into the ``(W, n)`` matrix the
single-host reference code consumes — but at n ~ 1e9 that materialization
is exactly the parameter-server bottleneck the Gram-space derivation in
:mod:`repro.core.gram` removes.  This module therefore never builds the
flat stack.  Instead it exploits two structural facts:

* **Gram additivity** — ``K = G G^T = sum_leaf  G_leaf G_leaf^T``: the
  (W, W) Gram matrix is one tall-skinny contraction over the packed leaf
  stream (``tree_gram``): the fused one-pass kernel in
  ``repro.kernels.gram`` issues a *single* ``pallas_call`` for the whole
  pytree (Pallas on TPU, XLA elsewhere; a per-shard psum on a real mesh),
  with the legacy per-leaf loop kept behind ``fused=False`` for the
  benchmarks.
* **Combine linearity** — any rule whose output is a fixed linear
  combination ``d = G^T c`` of worker gradients applies leafwise
  (``tree_combine``), a weighted reduction over the worker axis.

That covers FA itself (weights from ``fa_weights_from_gram``), PCA-top-m,
mean, geometric median (Weiszfeld runs in weight space: every iterate stays
in the gradient span, so distances are Gram-computable), and the
Krum-family selections (scores need only pairwise distances).  The
remaining baselines are coordinate-wise (median / trimmed mean / MeaMed /
Phocas), which commute with the pytree split and apply per leaf; Bulyan is
the hybrid — Gram-space selection via ``bulyan_select``, then the
coordinate-wise trimmed mean per leaf over the selected workers.  Every
path is *exactly* the flat reference (asserted at 2e-3 in
``tests/test_dist.py`` and generatively in ``tests/test_properties.py``).

``sketch_stride`` subsamples the gradient stream when forming the Gram
matrix (every stride-th chunk on the fused path, folded into the kernel
index map; rescaled so the diagonal stays unbiased) — an O(stride) cut in
Gram FLOPs/bytes used by the production configs; the combine always uses
the full gradients.

:func:`compressed_aggregate` is the worker->server compressed entry point:
it routes a ``repro.comm`` codec around ``aggregate_tree`` — sketch codecs
feed the Gram path directly (weights from compressed payloads, exact
combine), everything else goes through EF-compensated encode/decode.

Both entry points take ``sharded=`` to run mesh-native
(:mod:`repro.dist.sharded`): coordinate shards spread over the devices,
partial Grams meet in one ``(W, W)`` psum, the combine and the
coordinate-wise rules stay shard-local — no device ever holds the full
stack.  See docs/sharded_aggregation.md.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.analysis.contract import contract
from repro.comm.compressors import CommConfig, dense_bits, get_codec
from repro.comm.error_feedback import ef_encode_decode
from repro.core import aggregators
from repro.core.flag import FlagConfig
from repro.core.gram import fa_weights_from_gram
from repro.kernels.coord_stats.ops import (bulyan_select as bulyan_select_op,
                                           coord_stat,
                                           krum_scores as krum_scores_op)
from repro.kernels.gram.ops import gram as gram_kernel, tree_gram_fused
from repro.kernels.weighted_sum.ops import weighted_sum as weighted_sum_kernel

__all__ = ["AggregatorConfig", "tree_gram", "tree_combine", "aggregate_tree",
           "compressed_aggregate", "GRAM_RULES", "COORDWISE_RULES"]


@dataclass(frozen=True)
class AggregatorConfig:
    """Which rule the distributed step runs, and how the Gram is formed.

    ``f`` is the assumed Byzantine count (Krum family / trimming width);
    ``flag`` carries the FA hyper-parameters; ``sketch_stride`` > 1 sketches
    the Gram matrix (see module docstring); ``gram_dtype`` down-casts the
    leaf matrices before the Gram matmul (accumulation stays fp32);
    ``impl`` picks the kernel backend ('xla' | 'pallas' | 'pallas_interpret').
    """

    name: str = "flag"
    f: int = 1
    flag: FlagConfig = FlagConfig()
    sketch_stride: int = 1
    gram_dtype: str = "float32"
    impl: str = "xla"


def _leaf_matrix(leaf: jnp.ndarray, stride: int, dtype: str):
    """(W, ...) leaf -> ((W, n_kept) matrix, fp32 Gram rescale).

    Deterministic stride-subsample with the *exact* inverse kept fraction
    as the rescale (``n / n_kept`` — unbiased diagonal even when the leaf
    width is not a multiple of the stride).  The scale is returned
    separately and applied to the fp32 Gram accumulator, never to the
    matrix itself: folding it into a bf16 ``gram_dtype`` matrix would
    truncate the scale to bf16 before the contraction.  Leaves narrower
    than the stride keep every coordinate (scale 1, exact) instead of
    keeping one sample and inflating it ``stride``-fold.
    """
    M = leaf.reshape(leaf.shape[0], -1)
    scale = 1.0
    if stride > 1 and M.shape[1] > stride:
        n = M.shape[1]
        M = M[:, ::stride]
        scale = n / M.shape[1]
    if dtype != "float32":
        M = M.astype(jnp.dtype(dtype))
    return M, scale


def tree_gram(tree, sketch_stride: int = 1, *, gram_dtype: str = "float32",
              impl: str = "xla", fused: bool = True) -> jnp.ndarray:
    """(W, W) Gram matrix of the flattened worker gradients, one pass.

    Equals ``flat @ flat.T`` for the concatenated ``(W, n)`` matrix.
    The default *fused* path packs every leaf into a single worker-major
    chunk stream and issues exactly one kernel call for the whole pytree
    (one ``pallas_call`` on the Pallas backends; see
    ``repro.kernels.gram.ops.tree_gram_fused``), with ``sketch_stride``
    folded into the kernel index map — every stride-th block_n-wide chunk
    is read, the rest of HBM is skipped, and the result is rescaled by the
    exact inverse sampling fraction (diagonal-unbiased; weights only — the
    combine stays exact).  ``fused=False`` keeps the per-leaf loop (one
    dispatch + re-pad per leaf, element-stride sketching) as the
    reference/comparison path the benchmarks time against.

    Args:
      tree: worker-major pytree, every leaf shaped ``(W, ...)``.
      sketch_stride: fused path — keep every stride-th chunk of the packed
        stack; looped path — keep every stride-th coordinate of each leaf
        (leaves narrower than the stride stay exact), with the exact
        inverse kept fraction applied to the fp32 Gram.  Both keep the
        diagonal unbiased.
      gram_dtype: dtype the gradient stack is cast to *before* the matmul
        (accumulation stays fp32).
      impl: kernel backend — ``'xla'`` | ``'pallas'`` | ``'pallas_interpret'``.
      fused: one-pass fused kernel (default) vs per-leaf loop.
    Returns:
      ``(W, W)`` fp32 Gram matrix ``K`` with ``K[i, j] = <g_i, g_j>``.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("tree_gram: empty gradient pytree")
    if fused:
        return tree_gram_fused(leaves, sketch_stride=sketch_stride,
                               gram_dtype=gram_dtype, impl=impl)
    W = leaves[0].shape[0]
    K = jnp.zeros((W, W), jnp.float32)
    for leaf in leaves:
        M, scale = _leaf_matrix(leaf, sketch_stride, gram_dtype)
        # kernels.gram computes G^T G for column-major (n, p) input in fp32;
        # the sketch rescale is applied to the fp32 result (post-cast).
        K = K + gram_kernel(M.T, impl=impl) * scale
    return K


def tree_combine(tree, c: jnp.ndarray, *, impl: str = "xla"):
    """Weighted worker combine ``d = sum_w c_w g_w`` applied per leaf.

    The pytree analogue of ``flat.T @ c`` — the only n-dependent work of
    every linear-combination rule (a weighted all-reduce on a real mesh).

    Args:
      tree: worker-major pytree, every leaf shaped ``(W, ...)``.
      c: ``(W,)`` combination weights (cast to each leaf's dtype).
      impl: kernel backend — ``'xla'`` | ``'pallas'`` | ``'pallas_interpret'``.
    Returns:
      Pytree with the worker axis reduced away (leaf shapes ``(...)``).
    """
    def one(leaf):
        if impl != "xla":
            # the kernel upcasts both operands to fp32 in VMEM, so c keeps
            # full precision end to end; only the output is leaf-dtype.
            d = weighted_sum_kernel(
                leaf.reshape(leaf.shape[0], -1).T,
                c.astype(jnp.float32), impl=impl)
            return d.reshape(leaf.shape[1:])
        # contract in fp32 (c stays fp32, bf16 leaves accumulate in fp32
        # via preferred_element_type) and cast only the result — casting c
        # to bf16 first would truncate the combine weights before the
        # reduction.
        d = jax.lax.dot_general(
            c.astype(jnp.float32), leaf,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return d.astype(leaf.dtype)
    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# Gram-space combination weights per rule
# ---------------------------------------------------------------------------

def _geomed_weights(K: jnp.ndarray, n_iter: int = 8, eps: float = 1e-8,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Weiszfeld in weight space: z = G^T w stays in span(G), so
    ||g_i - z||^2 = K_ii - 2 (K w)_i + w^T K w.  Iterates identically to
    ``aggregators.geometric_median`` (init w = 1/p == init z = mean).
    With ``mask`` the weight support stays on active workers — every
    iterate is then the Weiszfeld step of the active submatrix.

    Degenerate memberships are exact by construction, not by luck: with a
    single active worker ``r`` has one nonzero entry, so the normalized
    iterate is that worker's exact one-hot (``r_i / r_i == 1.0`` in IEEE,
    independent of the ``eps`` distance clip); with zero active workers
    ``r`` is all-zero and the ``where`` keeps the previous (all-zero)
    iterate instead of dividing by the ``1e-30`` clamp — no NaN/Inf
    either way, even at ``eps = 0`` (regression-tested in
    ``tests/test_membership.py``)."""
    p = K.shape[0]
    eps = max(eps, 1e-30)                 # rsqrt(clip(., 0)) would be inf
    m = jnp.ones((p,), K.dtype) if mask is None else mask.astype(K.dtype)
    w0 = m / jnp.maximum(jnp.sum(m), 1.0)

    def body(w, _):
        Kw = K @ w
        d2 = jnp.diag(K) - 2.0 * Kw + w @ Kw
        r = jax.lax.rsqrt(jnp.clip(d2, eps)) * m
        s = jnp.sum(r)
        # s == 0 iff no active worker carries reweighting mass: w is
        # already the (all-zero) answer — keep it.
        return jnp.where(s > 0.0, r / jnp.maximum(s, 1e-30), w), None

    w, _ = jax.lax.scan(body, w0, None, length=n_iter)
    return w


def _selection_weights(K: jnp.ndarray, name: str, f: int,
                       impl: str = "xla") -> jnp.ndarray:
    """Krum-family combination weights from the Gram matrix."""
    p = K.shape[0]
    D2 = aggregators.sq_dists_from_gram(K)
    s = krum_scores_op(D2, f=f, impl=impl)
    if name == "krum":
        return jax.nn.one_hot(jnp.argmin(s), p, dtype=K.dtype)
    q = max(p - f - 2, 1)
    _, idx = jax.lax.top_k(-s, q)
    return jnp.zeros((p,), K.dtype).at[idx].add(1.0 / q)


def _gram_weights(K: jnp.ndarray, cfg: AggregatorConfig,
                  mask: jnp.ndarray | None = None):
    """(c, aux) for every rule expressible as a fixed combine d = G^T c.

    ``mask`` restricts every rule to the active worker subset (masked Gram
    rows — see repro.dist.membership); c is zero at inactive workers.
    """
    p = K.shape[0]
    if cfg.name == "flag":
        return fa_weights_from_gram(K, cfg.flag, mask=mask)
    if cfg.name == "pca":
        pca_cfg = FlagConfig(m=cfg.flag.m, lam=0.0, regularizer="none",
                             n_iter=1)
        return fa_weights_from_gram(K, pca_cfg, mask=mask)
    if cfg.name == "mean":
        if mask is None:
            return jnp.full((p,), 1.0 / p, K.dtype), {}
        m = mask.astype(K.dtype)
        return m / jnp.maximum(jnp.sum(m), 1.0), {}
    if cfg.name == "geomed":
        return _geomed_weights(K, mask=mask), {}
    if cfg.name in ("krum", "multi_krum"):
        if mask is None:
            return _selection_weights(K, cfg.name, cfg.f, cfg.impl), {}
        return aggregators.masked_selection_weights(
            aggregators.sq_dists_from_gram(K), cfg.name, cfg.f, mask), {}
    raise KeyError(cfg.name)


GRAM_RULES = frozenset({"flag", "pca", "mean", "geomed", "krum",
                        "multi_krum"})
COORDWISE_RULES = frozenset({"median", "trimmed_mean", "meamed", "phocas"})


@contract(fp32_contractions=True, no_host_transfers=True, mask_traced=True,
          no_full_width=True, kernel_race=True, kernel_budget=True)
def aggregate_tree(tree, cfg: AggregatorConfig, *, gram=None, mask=None,
                   sharded=None):
    """Aggregate a worker-major gradient pytree.

    Carries the graph contract (checked under ``REPRO_CONTRACTS=1`` /
    :func:`repro.analysis.enable_contracts`, free otherwise): fp32
    accumulation for every low-precision contraction, no host transfers
    in the graph, the membership mask consumed as a traced operand, and —
    with ``sharded=`` — no per-device tensor holding a full coordinate
    width.

    Args:
      tree: worker-major gradient pytree, every leaf shaped ``(W, ...)``.
      cfg: which rule runs and how the Gram matrix is formed.
      gram: optional precomputed ``(W, W)`` Gram estimate.  When given, the
        Gram-space rules (and Bulyan's selection) skip ``tree_gram`` and
        run their weight computation on it instead — this is how sketch
        codecs (``repro.comm``) feed FA with compressed payloads: weights
        come from the sketch Gram, the combine still uses the exact local
        gradients.  Coordinate-wise rules have no Gram stage, so passing
        ``gram`` for them is an error rather than a silent no-op.
      mask: optional (W,) active-worker membership (bool or 0/1 float, a
        *traced* value — see :mod:`repro.dist.membership`).  Every rule
        then operates on the active subset only: masked Gram rows for the
        FA/Krum family, masked leaves with dynamic order statistics for
        the coordinate rules.  Shapes are unchanged, so membership changes
        never recompile; inactive workers get combine weight exactly 0.
      sharded: mesh-shard the aggregation (:mod:`repro.dist.sharded`):
        the coordinate axis of every leaf spreads over the mesh devices,
        each device computes the partial Gram of its shard, the ``(W, W)``
        Gram meets in one ``psum``, weights run replicated, and the
        combine / coordinate rules stay shard-local — the full ``(W, n)``
        stack never exists on any device.  Pass a ``jax.sharding.Mesh``,
        or ``True`` to use the active :func:`repro.dist.sharding.
        use_sharding` mesh.  Composes with ``gram=`` (the override skips
        the psum stage) and ``mask=``.  ``None``/``False`` keeps the
        single-device path.
    Returns:
      ``(d_tree, aux)`` — ``d_tree`` has the worker axis reduced away (same
      treedef, leaf shapes ``(...)``); ``aux['weights']`` always holds a
      ``(W,)`` per-worker combination-weight vector (uniform for
      coordinate-wise rules, where no single linear combine exists) — the
      ``fa_weights`` training metric.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("aggregate_tree: empty gradient pytree")
    W = leaves[0].shape[0]
    if gram is not None and cfg.name in COORDWISE_RULES:
        raise ValueError(f"aggregator {cfg.name!r} is coordinate-wise and "
                         "cannot consume a precomputed Gram matrix")
    if mask is not None:
        mask = jnp.asarray(mask).astype(jnp.float32)

    if sharded:                       # Mesh instances are always truthy
        from jax.sharding import Mesh
        from repro.dist.sharded import sharded_aggregate_tree
        if isinstance(sharded, Mesh):
            mesh = sharded
        else:
            from repro.dist.sharding import current_mesh
            mesh = current_mesh()
            if mesh is None:
                raise ValueError(
                    "aggregate_tree(sharded=True) needs an active mesh: "
                    "wrap the call in repro.dist.sharding.use_sharding(...)"
                    " or pass sharded=<jax.sharding.Mesh>")
        return sharded_aggregate_tree(tree, cfg, mesh=mesh, gram=gram,
                                      mask=mask)

    if cfg.name in GRAM_RULES:
        K = gram if gram is not None else tree_gram(
            tree, cfg.sketch_stride, gram_dtype=cfg.gram_dtype,
            impl=cfg.impl)
        c, aux = _gram_weights(K, cfg, mask)
        d = tree_combine(tree, c, impl=cfg.impl)
        return d, {**aux, "weights": c}

    if cfg.name in COORDWISE_RULES:
        # Coordinate-wise rules commute with the pytree split: leafwise
        # application == the flat reference on the concatenated matrix.
        # coord_stat routes cfg.impl — the streaming Pallas selection
        # network or the jnp references — with identical (masked)
        # semantics either way.
        d = jax.tree.map(
            lambda g: coord_stat(g.reshape(W, -1), op=cfg.name, f=cfg.f,
                                 impl=cfg.impl, mask=mask
                                 ).reshape(g.shape[1:]),
            tree)
        if mask is None:
            return d, {"weights": jnp.full((W,), 1.0 / W, jnp.float32)}
        wa = jnp.maximum(jnp.sum(mask), 1.0)
        return d, {"weights": mask / wa}

    if cfg.name == "bulyan":
        # Selection is distance-only -> Gram space; the final trimmed mean
        # over the theta selected workers is coordinate-wise -> per leaf.
        K = gram if gram is not None else tree_gram(
            tree, cfg.sketch_stride, gram_dtype=cfg.gram_dtype,
            impl=cfg.impl)
        D2 = aggregators.sq_dists_from_gram(K)
        if mask is None:
            picks = bulyan_select_op(D2, f=cfg.f, impl=cfg.impl)
            theta = picks.shape[0]
            # Bulyan's coordinate stage IS MeaMed with f' = 2f on the
            # selected stack: mean of max(theta - 2f, 1) values closest to
            # the median — so the same streaming kernel serves both.
            def one(g):
                S = g.reshape(W, -1)[picks]
                return coord_stat(S, op="meamed", f=2 * cfg.f,
                                  impl=cfg.impl).reshape(g.shape[1:])

            d = jax.tree.map(one, tree)
            c = jnp.zeros((W,), jnp.float32).at[picks].add(1.0 / theta)
            return d, {"weights": c}

        selected, theta = aggregators.masked_bulyan_select(D2, cfg.f, mask)
        sel_f = selected.astype(jnp.float32)

        def one_masked(g):
            # masked MeaMed over the selected workers: W_a = theta, so the
            # keep-count max(W_a - 2f, 1) equals Bulyan's beta.
            return coord_stat(g.reshape(W, -1), op="meamed", f=2 * cfg.f,
                              impl=cfg.impl, mask=sel_f
                              ).reshape(g.shape[1:])

        d = jax.tree.map(one_masked, tree)
        return d, {"weights": sel_f / jnp.maximum(theta, 1)}

    raise KeyError(f"unknown aggregator {cfg.name!r}; have "
                   f"{sorted(GRAM_RULES | COORDWISE_RULES | {'bulyan'})}")


# ---------------------------------------------------------------------------
# codec x aggregator bridge (the worker->server compressed path)
# ---------------------------------------------------------------------------

@contract(fp32_contractions=True, no_host_transfers=True, mask_traced=True,
          no_full_width=True)
def compressed_aggregate(tree, cfg: AggregatorConfig,
                         comm: CommConfig = CommConfig(), ef=None, *,
                         mask=None, sharded=None):
    """Aggregate through a worker->server compression codec.

    Carries the same graph contract as :func:`aggregate_tree` (fp32
    contractions, no host transfers, traced mask, no per-device full
    coordinate width under a mesh), extended over the codec
    encode/decode and EF stages.

    Routing (see docs/compression.md for the dataflow diagrams):

    * ``comm.codec == 'none'`` — plain :func:`aggregate_tree`; the dense
      gradient tree is "the payload" (``comm_bits`` = fp32 baseline).
    * gram-feeding codec (CountSketch) x linear-combination rule — the
      *payload* forms the Gram estimate (``tree_gram`` over ``(W, k)``
      sketch leaves) and :func:`aggregate_tree` runs with ``gram=``: worker
      selection/weighting happens entirely on compressed representations,
      the combine is a weighted all-reduce of the workers' own exact
      gradients, and no decoded ``(W, n)`` stack is ever materialized
      (asserted via hlo_stats in ``tests/test_comm.py``).  Error feedback
      does not apply — the update direction is exact given the weights —
      so an *explicit* ``error_feedback=True`` opts out of this path and
      runs EF-compensated decode instead (EF on an untouched gram path
      would be a dead buffer pretending to be active).
    * everything else — EF-compensated encode/decode
      (:func:`repro.comm.error_feedback.ef_encode_decode`) followed by
      :func:`aggregate_tree` on the decoded worker-major estimates.

    Args:
      tree: worker-major gradient pytree, every leaf shaped ``(W, ...)``.
      cfg: aggregation rule config.
      comm: codec selection + hyper-parameters.
      ef: worker-major EF memory (``repro.comm.error_feedback.init_ef``)
        or ``None``.  Required iff ``comm.wants_ef``.
      mask: optional (W,) active-worker membership (see
        :mod:`repro.dist.membership`), forwarded to
        :func:`aggregate_tree`.  Inactive workers ship no bits
        (``comm_bits`` scales by the active fraction) and their EF memory
        is frozen, not updated, until they rejoin.
      sharded: forwarded to :func:`aggregate_tree` — mesh-shard the
        gradient coordinate axis (see :mod:`repro.dist.sharded`).  The
        sketch-Gram of a gram-feeding codec stays unsharded (payload
        leaves are ``(W, k)`` with k tiny by construction); everything
        n-sized — the decode, the dense Gram, the combine — runs
        shard-local.
    Returns:
      ``(d_tree, aux, new_ef)``; ``aux`` extends the aggregator aux with
      ``comm_bits`` (total bits shipped worker->server this step, from the
      codec's declared cost model) and ``comm_ratio`` (dense fp32 bits /
      ``comm_bits``).  ``new_ef`` is ``None`` iff ``ef`` was.
    """
    codec = get_codec(comm)
    bits_dense = dense_bits(tree)
    W = jax.tree.leaves(tree)[0].shape[0]
    # active fraction: the per-step cost model is per-worker-uniform, so an
    # absent worker's share simply doesn't travel.
    frac = (jnp.asarray(1.0) if mask is None
            else jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0) / W)
    if codec is None:
        d, aux = aggregate_tree(tree, cfg, mask=mask, sharded=sharded)
        return d, {**aux, "comm_bits": jnp.asarray(bits_dense) * frac,
                   "comm_ratio": jnp.asarray(1.0)}, ef
    if comm.wants_ef and ef is None:
        raise ValueError(
            f"codec {comm.codec!r} needs error feedback: pass "
            "ef=repro.comm.init_ef(params, workers) and thread the "
            "returned state (or set CommConfig(error_feedback=False))")

    bits = codec.bits(tree)
    stats = {"comm_bits": jnp.asarray(bits) * frac,
             "comm_ratio": jnp.asarray(bits_dense / bits)}

    if codec.gram_feed and cfg.name in GRAM_RULES and not comm.wants_ef:
        payload = codec.encode(tree)
        K = tree_gram(payload, gram_dtype=cfg.gram_dtype, impl=cfg.impl)
        d, aux = aggregate_tree(tree, cfg, gram=K, mask=mask,
                                sharded=sharded)
        return d, {**aux, **stats}, ef

    use_ef = ef if comm.wants_ef else None
    decoded, _, new_ef = ef_encode_decode(codec, tree, use_ef, mask=mask)
    d, aux = aggregate_tree(decoded, cfg, mask=mask, sharded=sharded)
    return d, {**aux, **stats}, (new_ef if comm.wants_ef else ef)
