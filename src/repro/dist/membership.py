"""Elastic worker membership: who is in the aggregation round, in-graph.

The Byzantine threat models (:mod:`repro.core.attacks`) simulate workers
that *lie*; this module simulates workers that *come and go* — crashes,
leaves/rejoins, rolling churn, stragglers that miss the synchronization
deadline.  Both families the paper's related work evaluates under
(Alistarh et al. 2018; Konstantinidis et al. 2022) are then one registry
lookup away from the train step.

Design constraints, mirroring the attacks layer:

* **Pure function of the step index.**  A :class:`FaultSchedule` is static
  Python data (tuples of :class:`FaultEvent`); :func:`membership_at` maps a
  *traced* ``step`` to the :class:`Membership` state with ordinary jnp ops.
  The whole fault simulation therefore compiles into the train step once —
  membership changes never alter an array shape and never retrigger
  compilation (asserted via compile counting in
  ``tests/test_membership.py``).
* **Masking, not slicing.**  The worker axis keeps its static size W; the
  active subset is a (W,) mask threaded into
  :func:`repro.dist.aggregation.aggregate_tree` (masked Gram rows for the
  FA/Krum family, masked leaves with dynamic order statistics for the
  coordinate rules) and into the EF memory update (an absent worker's
  error carry is frozen, not clobbered).

Semantics: a worker covered by any event interval at ``step`` is *out of
the round* — crashed, departed, or straggling past the sync deadline (an
elastic synchronous system drops late arrivals; their staleness is
telemetry).  ``staleness`` counts the consecutive steps (inclusive) the
worker has been out; 0 while active.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

__all__ = ["FaultEvent", "FaultSchedule", "Membership", "membership_at",
           "active_mask", "FAULTS", "get_fault_schedule"]

# "Forever" sentinel for crash events (any step beyond a real horizon).
NEVER = 1 << 30

KINDS = ("crash", "leave", "straggle")


@dataclass(frozen=True)
class FaultEvent:
    """One worker-outage interval: ``worker`` is out for ``[start, stop)``.

    ``kind`` is telemetry ('crash' | 'leave' | 'straggle') — the membership
    consequence is identical (out of the round); the elastic driver and the
    churn benchmark report it.
    """

    kind: str
    worker: int
    start: int
    stop: int = NEVER

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; have {KINDS}")
        if not 0 <= self.start < self.stop:
            raise ValueError(f"bad interval [{self.start}, {self.stop})")
        if self.worker < 0:
            raise ValueError(f"bad worker index {self.worker}")


@dataclass(frozen=True)
class FaultSchedule:
    """A static, hashable set of outage intervals (default: no faults)."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    @property
    def is_trivial(self) -> bool:
        return not self.events

    def max_worker(self) -> int:
        return max((e.worker for e in self.events), default=-1)


class Membership(NamedTuple):
    """Round membership state (a pytree; leaves are (W,) arrays).

    active: bool (W,) — in this aggregation round.
    staleness: int32 (W,) — consecutive steps out of the round (0 if active).
    """

    active: jnp.ndarray
    staleness: jnp.ndarray


def _merged_intervals(schedule: FaultSchedule):
    """Per-worker outage intervals with adjacent/overlapping events merged
    (static Python, runs at trace time).

    Merging keeps the staleness semantics honest: a worker out for
    ``[0, 5)`` and ``[5, 10)`` has been gone 8 consecutive steps at step
    7, not 3 — staleness counts from the merged interval's start.
    """
    per_worker: dict[int, list[list[int]]] = {}
    for e in sorted(schedule.events, key=lambda e: (e.worker, e.start)):
        ivs = per_worker.setdefault(e.worker, [])
        stop = min(e.stop, NEVER)
        if ivs and e.start <= ivs[-1][1]:
            ivs[-1][1] = max(ivs[-1][1], stop)
        else:
            ivs.append([e.start, stop])
    return [(w, s, t) for w, ivs in per_worker.items() for s, t in ivs]


def membership_at(schedule: FaultSchedule, step, W: int) -> Membership:
    """Membership state at a (possibly traced) ``step`` for W workers.

    Pure jnp: the event table lowers to constants, so this traces once and
    serves every step.  Workers named by no event are always active.
    """
    if schedule.max_worker() >= W:
        raise ValueError(
            f"fault schedule names worker {schedule.max_worker()} but the "
            f"step only has W={W} workers")
    step = jnp.asarray(step, jnp.int32)
    if schedule.is_trivial:
        return Membership(jnp.ones((W,), bool), jnp.zeros((W,), jnp.int32))
    ev = _merged_intervals(schedule)
    workers = jnp.asarray(np.array([w for w, _, _ in ev]), jnp.int32)
    starts = jnp.asarray(np.array([s for _, s, _ in ev]), jnp.int32)
    stops = jnp.asarray(np.array([t for _, _, t in ev]), jnp.int32)
    down = (step >= starts) & (step < stops)                  # (E,)
    down_w = jnp.zeros((W,), bool).at[workers].max(down)
    stale_e = jnp.where(down, step - starts + 1, 0)
    staleness = jnp.zeros((W,), jnp.int32).at[workers].max(stale_e)
    return Membership(~down_w, jnp.where(down_w, staleness, 0))


def active_mask(schedule: FaultSchedule, step, W: int) -> jnp.ndarray:
    """Float (W,) active mask at ``step`` (the aggregation-layer currency)."""
    return membership_at(schedule, step, W).active.astype(jnp.float32)


# ---------------------------------------------------------------------------
# scenario registry (mirrors repro.core.attacks.ATTACKS)
# ---------------------------------------------------------------------------

def _none(W: int) -> FaultSchedule:
    return FaultSchedule()


def _crash(W: int, *, n: int = 1, at: int = 10) -> FaultSchedule:
    """The last ``n`` workers crash at step ``at`` and never return (the
    last so crash and Byzantine sets don't overlap by default; capped at
    W-1 — a schedule never empties the quorum)."""
    n = min(n, W - 1)
    return FaultSchedule(tuple(
        FaultEvent("crash", W - 1 - i, at) for i in range(n)))


def _rejoin(W: int, *, n: int = 1, at: int = 10,
            down: int = 10) -> FaultSchedule:
    """``n`` workers leave at ``at`` and rejoin ``down`` steps later."""
    n = min(n, W - 1)
    return FaultSchedule(tuple(
        FaultEvent("leave", W - 1 - i, at, at + down) for i in range(n)))


def _churn(W: int, *, period: int = 5, horizon: int = 200) -> FaultSchedule:
    """Rolling membership: every ``period`` steps the next worker (round-
    robin) drops out for one period — continuous joins *and* leaves."""
    events = []
    for r in range(max(horizon // period, 1)):
        events.append(FaultEvent("leave", r % W,
                                 r * period, (r + 1) * period))
    return FaultSchedule(tuple(events))


def _straggle(W: int, *, n: int = 1, every: int = 10,
              duration: int = 3, horizon: int = 200) -> FaultSchedule:
    """``n`` workers periodically miss ``duration`` sync deadlines."""
    n = min(n, W - 1)
    events = []
    for start in range(every, max(horizon, every + 1), every):
        for i in range(n):
            events.append(FaultEvent("straggle", W - 1 - i, start,
                                     start + min(duration, every)))
    return FaultSchedule(tuple(events))


FAULTS = {
    "none": _none,
    "crash": _crash,
    "rejoin": _rejoin,
    "churn": _churn,
    "straggle": _straggle,
}


def get_fault_schedule(name: str, W: int, **kw) -> FaultSchedule:
    """Build a named fault scenario for ``W`` workers."""
    if name not in FAULTS:
        raise KeyError(f"unknown fault scenario {name!r}; have "
                       f"{sorted(FAULTS)}")
    return FAULTS[name](W, **kw)
