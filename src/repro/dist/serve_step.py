"""Serving steps: prefill, one-token greedy decode, and the decode loop.

``build_serve_step`` returns the single jit-able unit of the serving path:
one token in, one greedy token out, KV/recurrent caches threaded through.
The cache layout is whatever :func:`repro.models.transformer.init_caches`
produced — a ring buffer of size ``window`` for sliding-window archs, the
full ``max_len`` otherwise — and is *static* per compilation, so the same
step function serves every position (the scalar ``step`` counter is the
only thing that changes).

``decode_loop`` is the batched driver used by ``examples/serve_decode.py``:
it feeds the prompt token-by-token through the same step function (so the
compiled program is identical for prefill-by-decode and generation — one
compilation per (arch, batch, max_len)), then generates greedily.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig

__all__ = ["build_prefill_step", "build_serve_step", "decode_loop"]


def build_prefill_step(cfg: ModelConfig, *, attn_impl: str = "xla"):
    """Build the full-sequence scoring step.

    Args:
      cfg: model config.
      attn_impl: ``'xla'`` (host / dry-run) or ``'pallas'`` (TPU).
    Returns:
      ``prefill(params, batch) -> logits (B, S, V)`` — used for request
      scoring; ``batch`` is ``{tokens (B, S)[, prefix_embeds]}``.
    """

    def prefill_step(params, batch):
        return transformer.prefill(params, batch, cfg, attn_impl=attn_impl)

    return prefill_step


def build_serve_step(cfg: ModelConfig, *, max_len: int):
    """Build the one-token greedy decode step.

    Args:
      cfg: model config.
      max_len: static cache length the step compiles against.
    Returns:
      ``serve(params, caches, tokens, step) -> (next_tokens, caches)`` —
      ``tokens`` is ``(B, 1)`` int32, ``step`` a scalar int32 position,
      ``next_tokens`` the ``(B, 1)`` int32 greedy argmax; cache layout is
      whatever ``transformer.init_caches`` produced.
    """

    def serve_step(params, caches, tokens, step):
        logits, caches = transformer.decode_step(params, tokens, caches,
                                                 step, cfg, max_len=max_len)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, caches

    return serve_step


def decode_loop(params, cfg: ModelConfig, prompts, *, num_steps: int,
                max_len: int, cache_dtype=jnp.float32):
    """Greedy generation driver over the compiled serve step.

    The prompt is consumed through the same compiled serve step used for
    generation (lockstep batch decoding; prompt logits are discarded except
    the last, which seeds the first generated token), so there is one
    compilation per (arch, batch, max_len).

    Args:
      params: model parameters.
      cfg: model config.
      prompts: ``(B, S)`` int32 prompt tokens, ``S >= 1`` (the last prompt
        token's logits seed generation, so an empty prompt has nothing to
        condition on — prepend a BOS token to generate unconditionally).
      num_steps: number of tokens to generate.
      max_len: static cache length; requires ``S + num_steps <= max_len``.
      cache_dtype: KV/recurrent cache dtype.
    Returns:
      ``(B, num_steps)`` int32 greedily generated tokens.
    """
    B, S = prompts.shape
    if S == 0:
        raise ValueError(
            "decode_loop needs a non-empty prompt (S >= 1): generation is "
            "seeded by the last prompt token's logits.  To generate "
            "unconditionally, pass a (B, 1) BOS-token prompt instead")
    if num_steps < 1:
        raise ValueError(f"decode_loop needs num_steps >= 1, got "
                         f"{num_steps}")
    if S + num_steps > max_len:
        raise ValueError(f"prompt ({S}) + generation ({num_steps}) exceeds "
                         f"max_len={max_len}")
    caches = transformer.init_caches(cfg, B, max_len, cache_dtype)
    step_fn = jax.jit(build_serve_step(cfg, max_len=max_len))

    for t in range(S):
        tok, caches = step_fn(params, caches, prompts[:, t:t + 1],
                              jnp.asarray(t, jnp.int32))
    # the prompt loop's last step already produced generated token 0, so
    # only num_steps - 1 further forwards are needed.
    out = [tok]
    for t in range(S, S + num_steps - 1):
        tok, caches = step_fn(params, caches, tok,
                              jnp.asarray(t, jnp.int32))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
