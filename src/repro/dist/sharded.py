"""Mesh-sharded aggregation: partial-Gram psum, shard-local everything else.

:mod:`repro.dist.aggregation` never materializes the flat ``(W, n)``
gradient stack — but it still assumes the whole worker-major pytree lives
on a *single device*.  This module removes that assumption.  The key fact
is Gram additivity over any coordinate partition:

    K = G G^T = sum_s  G[:, s] G[:, s]^T        (s = coordinate shards)

so aggregation decomposes into three stages with *one* tiny collective:

1. **partial Gram, shard-local** — each device holds a coordinate shard
   ``(W, n / n_shards)`` of every leaf and computes its partial Gram with
   the same fused chunk schedule as the single-device path
   (``repro.kernels.gram``), then ``psum``s the ``(W, W)`` result over the
   mesh axes.  ``W * W`` floats is the entire wire traffic.
2. **weights, replicated** — the rule's weight computation (the rank-p
   IRLS for FA, Weiszfeld, Krum scores, ...) sees only the psum'd Gram.
   It is O(p^3) with p = W, so running it replicated on every device is
   cheaper than any attempt to distribute it.
3. **combine, shard-local** — ``d = sum_w c_w g_w`` is per-coordinate, so
   each device combines its own shard; coordinate-wise rules (median /
   trimmed mean / MeaMed / Phocas, Bulyan's final stage) are *also*
   per-coordinate and run shard-local with zero communication.

The full unsharded stack therefore never exists on any device: the only
cross-device values are the ``(W, W)`` Gram and the ``(W,)`` weight
vector (asserted via post-partition HLO shape inspection in
``tests/test_sharded_agg.py``).

Layout: every leaf ``(W, ...)`` is viewed as ``(W, n_shards, chunk)``
(zero-padded up to a multiple of ``n_shards`` — padding contributes 0 to
the Gram and is sliced off after the combine) with the middle axis
sharded over *all* mesh axes, i.e. ``P(None, ('data', 'model'), None)``
on the production mesh.  ``shard_map`` then hands each device its
``(W, 1, chunk)`` block.  Equivalence with the single-device path is
exact for the combine (bit-identical given the same weights — the
per-coordinate reduction order over workers is unchanged) and fp32-
rounding-exact for the Gram (the psum reassociates the coordinate sum).

``sketch_stride`` composes: each shard samples its *local* chunk stream
with the shared ``chunk_schedule``, so the sketch subset is per-shard
deterministic (it differs from the single-device subset — both are
unbiased estimates of the same Gram).

Entry point: ``aggregate_tree(..., sharded=mesh)`` /
``compressed_aggregate(..., sharded=True)`` route here — see
:func:`sharded_aggregate_tree` and docs/sharded_aggregation.md.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.contract import contract

__all__ = ["coord_axes", "n_coord_shards", "sharded_tree_gram",
           "sharded_tree_combine", "sharded_aggregate_tree"]


def coord_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes the gradient coordinate dim shards over: all of them.

    The Gram psum reduces over the whole mesh, so there is no reason to
    leave an axis out — a ``(pod, data, model)`` mesh shards coordinates
    ``pod * data * model`` ways.
    """
    return tuple(mesh.axis_names)


def n_coord_shards(mesh: Mesh, axes: tuple[str, ...] | None = None) -> int:
    axes = coord_axes(mesh) if axes is None else axes
    return math.prod(mesh.shape[a] for a in axes)


def _to_view(leaf: jnp.ndarray, shards: int):
    """(W, ...) leaf -> ((W, shards, chunk) device view, flat width n)."""
    M = leaf.reshape(leaf.shape[0], -1)
    n = M.shape[1]
    chunk = -(-n // shards)
    pad = shards * chunk - n
    if pad:
        M = jnp.pad(M, ((0, 0), (0, pad)))
    return M.reshape(M.shape[0], shards, chunk), n


def _from_view(out: jnp.ndarray, n: int, shape: tuple[int, ...],
               mesh: Mesh, axes: tuple[str, ...]):
    """(shards, chunk) combined output -> original trailing leaf shape.

    The flat form keeps its sharding constraint whenever the slice is a
    no-op (no padding was added), so a cleanly-divisible stack stays
    sharded end to end; padded leaves pay one boundary reshard.
    """
    flat = out.reshape(-1)
    if flat.shape[0] == n:
        flat = jax.lax.with_sharding_constraint(
            flat, NamedSharding(mesh, P(axes)))
    else:
        flat = flat[:n]
    return flat.reshape(shape)


def _views(leaves, mesh: Mesh, axes: tuple[str, ...]):
    shards = n_coord_shards(mesh, axes)
    views, ns = [], []
    spec = NamedSharding(mesh, P(None, axes, None))
    for leaf in leaves:
        v, n = _to_view(leaf, shards)
        views.append(jax.lax.with_sharding_constraint(v, spec))
        ns.append(n)
    return views, ns


def _leafwise_shard_map(leaves, mesh: Mesh, axes: tuple[str, ...], fn,
                        *extras):
    """Run ``fn((W, n_local) matrix, *extras) -> (n_local,)`` per leaf
    inside one ``shard_map`` over the coordinate shards.

    ``extras`` are replicated inputs (weights, masks, selections).
    Returns the per-leaf worker-reduced arrays in the leaves' original
    trailing shapes.
    """
    views, ns = _views(leaves, mesh, axes)
    W = leaves[0].shape[0]
    spec_in = P(None, axes, None)
    spec_out = P(axes, None)

    def local(extras_, *xs):
        return tuple(fn(x.reshape(W, -1), *extras_).reshape(1, -1)
                     for x in xs)

    outs = shard_map(local, mesh=mesh,
                     in_specs=(P(),) + (spec_in,) * len(views),
                     out_specs=(spec_out,) * len(views),
                     check_rep=False)(tuple(extras), *views)
    return [_from_view(o, n, leaf.shape[1:], mesh, axes)
            for o, n, leaf in zip(outs, ns, leaves)]


def sharded_tree_gram(tree, mesh: Mesh, *, sketch_stride: int = 1,
                      gram_dtype: str = "float32", impl: str = "xla",
                      axes: tuple[str, ...] | None = None) -> jnp.ndarray:
    """(W, W) Gram of a coordinate-sharded worker-major pytree.

    Each device runs the fused single-device ``tree_gram`` on its local
    ``(W, chunk)`` shards (same kernel, same chunk schedule, applied to
    the local stream) and the partial Grams meet in one ``psum``.

    Args:
      tree: worker-major pytree, every leaf shaped ``(W, ...)``.
      mesh: the mesh whose devices hold the coordinate shards.
      sketch_stride: per-shard chunk sampling (see module docstring).
      gram_dtype / impl: forwarded to the per-shard ``tree_gram``.
      axes: mesh axes to shard coordinates over (default: all).
    Returns:
      ``(W, W)`` fp32 Gram, replicated (an unsharded global array).
    """
    from repro.dist.aggregation import tree_gram
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("sharded_tree_gram: empty gradient pytree")
    axes = coord_axes(mesh) if axes is None else axes
    views, _ = _views(leaves, mesh, axes)
    W = leaves[0].shape[0]
    spec_in = P(None, axes, None)

    def local(*xs):
        K = tree_gram([x.reshape(W, -1) for x in xs], sketch_stride,
                      gram_dtype=gram_dtype, impl=impl)
        return jax.lax.psum(K, axes)

    return shard_map(local, mesh=mesh, in_specs=(spec_in,) * len(views),
                     out_specs=P(), check_rep=False)(*views)


def sharded_tree_combine(tree, c: jnp.ndarray, mesh: Mesh, *,
                         impl: str = "xla",
                         axes: tuple[str, ...] | None = None):
    """Shard-local ``d = sum_w c_w g_w``: zero cross-device traffic.

    The combine is per-coordinate, so each device reduces the worker axis
    of its own shard; given identical weights the result is bit-identical
    to the single-device ``tree_combine`` (same per-coordinate reduction).

    Args:
      tree: worker-major pytree, every leaf shaped ``(W, ...)``.
      c: ``(W,)`` combination weights (replicated).
      mesh / axes: coordinate-shard layout (default: all mesh axes).
      impl: kernel backend for the per-shard combine.
    Returns:
      Pytree with the worker axis reduced away, coordinate-sharded.
    """
    from repro.dist.aggregation import tree_combine
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("sharded_tree_combine: empty gradient pytree")
    axes = coord_axes(mesh) if axes is None else axes

    def one(M, c_):
        return tree_combine([M], c_, impl=impl)[0]

    outs = _leafwise_shard_map(leaves, mesh, axes, one, c)
    return treedef.unflatten(outs)


@contract(fp32_contractions=True, no_host_transfers=True, mask_traced=True,
          no_full_width=True, kernel_race=True, kernel_budget=True)
def sharded_aggregate_tree(tree, cfg, *, mesh: Mesh, gram=None, mask=None):
    """Mesh-sharded :func:`repro.dist.aggregation.aggregate_tree`.

    Same contract and return value as the single-device path (including
    ``gram=`` / ``mask=`` composition) with the dataflow of the module
    docstring: psum'd partial Grams, replicated weights, shard-local
    combine / coordinate rules.  Call through
    ``aggregate_tree(..., sharded=...)`` rather than directly.
    """
    from repro.dist import aggregation as agg
    from repro.core import aggregators

    leaves, treedef = jax.tree.flatten(tree)
    W = leaves[0].shape[0]
    axes = coord_axes(mesh)

    def psummed_gram():
        if gram is not None:
            return gram
        return sharded_tree_gram(tree, mesh, sketch_stride=cfg.sketch_stride,
                                 gram_dtype=cfg.gram_dtype, impl=cfg.impl,
                                 axes=axes)

    if cfg.name in agg.GRAM_RULES:
        K = psummed_gram()
        # Weight computation on the (W, W) Gram: replicated by SPMD — at
        # O(p^3), p = W, this is cheaper everywhere than distributing it.
        c, aux = agg._gram_weights(K, cfg, mask)
        d = sharded_tree_combine(tree, c, mesh, impl=cfg.impl, axes=axes)
        return d, {**aux, "weights": c}

    if cfg.name in agg.COORDWISE_RULES:
        # Coordinate-wise rules commute with the coordinate sharding:
        # each device applies the rule to its own shard, no communication.
        # coord_stat dispatches cfg.impl per shard — the per-coordinate
        # math is independent of the shard blocking, so the sharded result
        # is bit-identical to single-device on either backend.
        from repro.kernels.coord_stats.ops import coord_stat
        if mask is None:
            outs = _leafwise_shard_map(
                leaves, mesh, axes,
                lambda M: coord_stat(M, op=cfg.name, f=cfg.f, impl=cfg.impl))
            return treedef.unflatten(outs), {
                "weights": jnp.full((W,), 1.0 / W, jnp.float32)}
        outs = _leafwise_shard_map(
            leaves, mesh, axes,
            lambda M, m: coord_stat(M, op=cfg.name, f=cfg.f, impl=cfg.impl,
                                    mask=m), mask)
        wa = jnp.maximum(jnp.sum(mask), 1.0)
        return treedef.unflatten(outs), {"weights": mask / wa}

    if cfg.name == "bulyan":
        # Selection is Gram-only (replicated); the trimmed mean over the
        # selected workers is coordinate-wise (shard-local).
        K = psummed_gram()
        D2 = aggregators.sq_dists_from_gram(K)
        from repro.kernels.coord_stats.ops import bulyan_select, coord_stat
        if mask is None:
            picks = bulyan_select(D2, f=cfg.f, impl=cfg.impl)
            theta = picks.shape[0]

            # Bulyan's coordinate stage == MeaMed with f' = 2f on the
            # selected stack (keep-count max(theta - 2f, 1) = beta).
            def one(M, picks_):
                return coord_stat(M[picks_], op="meamed", f=2 * cfg.f,
                                  impl=cfg.impl)

            outs = _leafwise_shard_map(leaves, mesh, axes, one, picks)
            c = jnp.zeros((W,), jnp.float32).at[picks].add(1.0 / theta)
            return treedef.unflatten(outs), {"weights": c}

        selected, theta = aggregators.masked_bulyan_select(D2, cfg.f, mask)
        sel_f = selected.astype(jnp.float32)

        def one_masked(M, sel):
            # masked MeaMed with W_a = theta: keep-count max(theta-2f, 1).
            return coord_stat(M, op="meamed", f=2 * cfg.f, impl=cfg.impl,
                              mask=sel)

        outs = _leafwise_shard_map(leaves, mesh, axes, one_masked, sel_f)
        return treedef.unflatten(outs), {
            "weights": sel_f / jnp.maximum(theta, 1)}

    raise KeyError(f"unknown aggregator {cfg.name!r}")
