"""Logical-axis sharding: mesh-agnostic annotations for model code.

Model code never names mesh axes.  It annotates values with *logical* axes
(``shard(x, ("sub_batch", "seq", "embed"))``); a launcher activates a mesh
plus a logical->mesh translation with :func:`use_sharding`, and every
annotation becomes a GSPMD sharding constraint.  Outside any context —
single-host tests, CPU smoke runs, benchmarks — ``shard`` is the identity,
so the exact same model code runs everywhere.

Resolution rules (in priority order):

  1. ``None`` logical entries and names missing from the rule set resolve to
     unconstrained dimensions.
  2. A rule value may be a mesh-axis name, a tuple of mesh axes (the dim is
     sharded over their product, e.g. ``worker -> ("pod", "data")``), or
     ``None`` (explicitly replicated).
  3. A mesh axis is consumed at most once per value (GSPMD forbids reuse);
     later dimensions that map to an already-used axis stay unconstrained —
     this is what makes annotations like ``("embed", "embed")`` legal.
  4. A dimension whose size does not divide the mapped axis product stays
     unconstrained rather than erroring, so reduced smoke configs lower
     under production rule sets.

``DEFAULT_RULES`` encodes the production 16x16 (data, model) layout:
Megatron-style tensor parallelism on ``model`` for every contraction dim,
FA workers / batch on the data axes.  ``use_sharding(mesh, overrides)``
starts from these defaults (widening worker/batch to ``(pod, data)`` when
the mesh has a pod axis) and applies per-arch overrides on top — see
``launch.dryrun.rules_for`` for the per-arch derivations.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["shard", "shard_grad_stack", "use_sharding", "current_mesh",
           "current_rules", "logical_spec", "DEFAULT_RULES"]


# Logical axis vocabulary (the full set the model substrate annotates with):
#   worker      — the FA worker axis of worker-major batches / gradients
#   batch       — global data batch (training inputs)
#   sub_batch   — per-worker batch inside the vmapped loss
#   seq / cache_seq — sequence and KV-cache length
#   embed       — d_model residual stream
#   vocab       — embedding / unembedding vocabulary dim
#   mlp / qkv   — FFN hidden and attention projection contraction dims
#   heads / kv_heads / head_dim — attention head layout
#   experts / expert_mlp — MoE expert bank layout (EP vs TP)
#   state       — recurrent-cell widths (rglru / xLSTM)
#   grad_worker / grad_coord — the worker-major gradient *stack* under
#     sharded aggregation (repro.dist.sharded): the worker axis is
#     replicated (every device sees all W rows of its coordinate shard)
#     and the leading coordinate axis spreads over the WHOLE mesh — the
#     transpose of the per-worker data layout, entered once per step at
#     the aggregation boundary instead of gathering the stack anywhere.
DEFAULT_RULES: dict[str, Any] = {
    "worker": ("data",),
    "batch": ("data",),
    "grad_worker": None,
    "grad_coord": ("data", "model"),
    "sub_batch": None,
    "seq": None,
    "cache_seq": None,
    "embed": None,
    "vocab": "model",
    "mlp": "model",
    "qkv": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": None,
    "expert_mlp": "model",
    "state": "model",
}


@dataclass(frozen=True)
class _ShardCtx:
    mesh: Mesh
    rules: Mapping[str, Any]


_CTX: ContextVar[_ShardCtx | None] = ContextVar("repro_shard_ctx",
                                                default=None)


def current_mesh() -> Mesh | None:
    ctx = _CTX.get()
    return ctx.mesh if ctx else None


def current_rules() -> Mapping[str, Any] | None:
    ctx = _CTX.get()
    return ctx.rules if ctx else None


@contextmanager
def use_sharding(mesh: Mesh, rules: Mapping[str, Any] | None = None):
    """Activate ``mesh`` + logical rules for every ``shard`` call inside.

    Args:
      mesh: the ``jax.sharding.Mesh`` to constrain against.
      rules: optional per-logical-name overrides of :data:`DEFAULT_RULES`.
        A value may be a mesh-axis name, a tuple of mesh axes (the dim
        shards over their product), or ``None`` (explicitly replicated).
    Yields:
      Nothing — on exit the previous context (usually "no sharding") is
      restored.  On meshes with a ``pod`` axis the worker/batch defaults
      widen to ``(pod, data)`` (the multi-pod FA worker axis) before
      overrides apply.
    """
    resolved = dict(DEFAULT_RULES)
    if "pod" in mesh.shape:
        resolved["worker"] = ("pod", "data")
        resolved["batch"] = ("pod", "data")
        # the coordinate shards of the gradient stack span the WHOLE mesh
        # (repro.dist.sharded psums over every axis), so they widen too —
        # otherwise the stack would arrive pod-replicated and pay a full
        # cross-pod reshard at the aggregation boundary.
        resolved["grad_coord"] = ("pod", "data", "model")
    if rules:
        resolved.update(rules)
    token = _CTX.set(_ShardCtx(mesh, resolved))
    try:
        yield
    finally:
        _CTX.reset(token)


def _as_axis_tuple(mapped: Any) -> tuple[str, ...]:
    if mapped is None:
        return ()
    if isinstance(mapped, str):
        return (mapped,)
    return tuple(mapped)


def logical_spec(shape: Sequence[int], axes: Sequence[str | None],
                 mesh: Mesh, rules: Mapping[str, Any]) -> P:
    """Translate logical ``axes`` to a PartitionSpec under ``rules``.

    Applies the resolution rules documented in the module docstring
    (unknown -> unconstrained, one use per mesh axis, divisibility guard).
    """
    if len(axes) != len(shape):
        raise ValueError(f"logical axes {tuple(axes)} do not match "
                         f"rank-{len(shape)} value of shape {tuple(shape)}")
    used: set[str] = set()
    entries: list[Any] = []
    for dim, name in zip(shape, axes):
        mapped = rules.get(name) if name is not None else None
        axs = tuple(a for a in _as_axis_tuple(mapped)
                    if a in mesh.shape and a not in used)
        size = math.prod(mesh.shape[a] for a in axs) if axs else 1
        if axs and size > 1 and dim % size == 0:
            entries.append(axs if len(axs) > 1 else axs[0])
            used.update(axs)
        else:
            entries.append(None)
    return P(*entries)


def shard(x, axes: Sequence[str | None]):
    """Constrain ``x`` to the active mesh along logical ``axes``.

    Args:
      x: array to annotate; ``len(axes)`` must equal ``x.ndim``.
      axes: one logical axis name (see the vocabulary above
        :data:`DEFAULT_RULES`) or ``None`` per dimension, e.g.
        ``shard(h, ("sub_batch", "seq", "embed"))`` for a ``(B, S, D)``
        activation.
    Returns:
      ``x`` wrapped in a GSPMD sharding constraint under the active
      :func:`use_sharding` context — or ``x`` unchanged when no context
      is active (single-host tests / CPU benchmarks), so model code is
      unconditionally annotated.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = logical_spec(x.shape, axes, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def shard_grad_stack(tree):
    """Constrain a worker-major gradient pytree to the sharded-aggregation
    layout: worker axis replicated, leading coordinate axis spread over
    ``grad_coord`` (the whole mesh by default).

    This is the "sharded by construction" entry into
    :mod:`repro.dist.sharded` — GSPMD redistributes the per-worker
    gradients straight into coordinate shards at the aggregation
    boundary, with no gather to a single device in between.  Dimensions
    that do not divide the mesh stay unconstrained (rule 4 above), so
    reduced smoke configs lower unchanged.  Identity outside a
    :func:`use_sharding` context, like :func:`shard`.
    """
    def one(leaf):
        if leaf.ndim < 2:
            return shard(leaf, ("grad_worker",) if leaf.ndim else ())
        return shard(leaf, ("grad_worker", "grad_coord")
                     + (None,) * (leaf.ndim - 2))
    return jax.tree.map(one, tree)
