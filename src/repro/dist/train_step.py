"""The distributed train step: per-worker grads -> attack -> aggregate -> update.

One pure function of ``(params, opt_state, batch, rng, step)`` so the whole
pipeline jits (and pjits on a mesh) as a single program:

  1. **Per-worker gradients** — the worker-major batch ``{tokens (W,B,S),
     labels (W,B,S)[, prefix_embeds]}`` goes through ``vmap(value_and_grad)``
     over the worker axis; on a mesh the worker axis shards over
     ``(pod, data)`` so this is ordinary data parallelism.  With
     ``microbatch_splits > 1`` each worker accumulates its gradient over
     sequential micro-batches (a ``lax.scan``), bounding activation memory.
  2. **In-graph attack injection** — ``repro.core.attacks`` corrupts the
     first ``attack_f`` workers' gradients *inside* the graph, so Byzantine
     simulations compile into the same program they benchmark.
  3. **Aggregation** — :func:`repro.dist.aggregation.aggregate_tree`; FA
     runs in Gram space (the flat (W, n) matrix is never materialized).
  4. **Update** — ``repro.optim`` transform + ``apply_updates``.

Metrics: ``loss`` (mean over workers, pre-attack — honest telemetry),
``lr``, ``grad_global_norm`` (of the aggregated update direction),
``fa_weights`` (the (W,) raw combination weights c — the paper's worker
"value" signal), and ``worker_influence`` (|c_i| * ||g_i|| normalized to
sum 1: each worker's share of the aggregated update's mass.  Raw c is the
right paper-faithful quantity but misleading under degenerate norms — a
zero-gradient Byzantine worker gets a huge c yet contributes nothing —
so the Byzantine-dominance tests assert on influence).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import attacks
from repro.dist.aggregation import AggregatorConfig, aggregate_tree
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import Optimizer, apply_updates

__all__ = ["TrainConfig", "init_train_state", "build_train_step",
           "global_norm"]


@dataclass(frozen=True)
class TrainConfig:
    """Distributed-step settings orthogonal to the model config."""

    aggregator: AggregatorConfig = AggregatorConfig()
    attack: str = "none"              # repro.core.attacks registry name
    attack_f: int = 0                 # Byzantine worker count (first f)
    microbatch_splits: int = 1        # grad-accumulation splits per worker
    attn_impl: str = "xla"            # 'xla' (host / dry-run) | 'pallas' (TPU)


def init_train_state(key, cfg: ModelConfig, opt: Optimizer):
    """-> (params, opt_state) for one model replica."""
    params = transformer.init_params(key, cfg)
    return params, opt.init(params)


def global_norm(tree) -> jnp.ndarray:
    """L2 norm over every leaf of a pytree (fp32)."""
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def build_train_step(cfg: ModelConfig, tc: TrainConfig, opt: Optimizer,
                     sched, *, grad_shardings=None, param_shardings=None):
    """Build ``step(params, opt_state, batch, rng, step_idx)``.

    ``sched`` maps the int32 step index to a learning rate.  The optional
    ``grad_shardings`` / ``param_shardings`` pin the worker-major gradient
    pytree and the updated params to explicit shardings (the dry-run passes
    GSPMD-propagated layouts; ``None`` lets XLA choose).
    Returns ``(new_params, new_opt_state, metrics)``.
    """

    def loss_fn(params, wb):
        return transformer.forward(params, wb, cfg, attn_impl=tc.attn_impl)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def worker_grad(params, wb):
        """Gradient + metrics for ONE worker's (B, ...) batch."""
        k = tc.microbatch_splits
        if k <= 1:
            (_, metrics), g = grad_fn(params, wb)
            return g, metrics
        mb = jax.tree.map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), wb)
        m_shapes = jax.eval_shape(
            lambda p, b: loss_fn(p, b)[1], params,
            jax.tree.map(lambda x: x[0], mb))

        def accum(carry, b):
            acc_g, acc_m = carry
            (_, m), g = grad_fn(params, b)
            return (jax.tree.map(jnp.add, acc_g, g),
                    jax.tree.map(jnp.add, acc_m, m)), None

        zeros = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params),
                 jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              m_shapes))
        (g, m), _ = jax.lax.scan(accum, zeros, mb)
        inv = 1.0 / k
        return (jax.tree.map(lambda t: t * inv, g),
                jax.tree.map(lambda t: t * inv, m))

    def step(params, opt_state, batch, rng, step_idx):
        grads, metrics_w = jax.vmap(worker_grad, in_axes=(None, 0))(
            params, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)

        if tc.attack != "none" and tc.attack_f > 0:
            grads = attacks.apply_attack_tree(tc.attack, grads, rng,
                                              tc.attack_f)

        d, agg_aux = aggregate_tree(grads, tc.aggregator)

        lr = sched(step_idx)
        updates, new_opt_state = opt.update(d, opt_state, params, lr)
        new_params = apply_updates(params, updates)
        if param_shardings is not None:
            new_params = jax.lax.with_sharding_constraint(new_params,
                                                          param_shardings)

        c = agg_aux["weights"].astype(jnp.float32)
        worker_norms = jnp.sqrt(sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)),
                    axis=tuple(range(1, l.ndim)))
            for l in jax.tree.leaves(grads)))
        influence = jnp.abs(c) * worker_norms
        influence = influence / jnp.maximum(jnp.sum(influence), 1e-20)

        metrics = {k: jnp.mean(v) for k, v in metrics_w.items()}
        metrics["lr"] = lr
        metrics["grad_global_norm"] = global_norm(d)
        metrics["fa_weights"] = c
        metrics["worker_influence"] = influence
        return new_params, new_opt_state, metrics

    return step
