"""The distributed train step: per-worker grads -> attack -> aggregate -> update.

One pure function of ``(params, opt_state, batch, rng, step)`` so the whole
pipeline jits (and pjits on a mesh) as a single program:

  1. **Per-worker gradients** — the worker-major batch ``{tokens (W,B,S),
     labels (W,B,S)[, prefix_embeds]}`` goes through ``vmap(value_and_grad)``
     over the worker axis; on a mesh the worker axis shards over
     ``(pod, data)`` so this is ordinary data parallelism.  With
     ``microbatch_splits > 1`` each worker accumulates its gradient over
     sequential micro-batches (a ``lax.scan``), bounding activation memory.
  2. **In-graph attack injection** — ``repro.core.attacks`` corrupts the
     first ``attack_f`` workers' gradients *inside* the graph, so Byzantine
     simulations compile into the same program they benchmark.
  3. **Compression + aggregation** —
     :func:`repro.dist.aggregation.compressed_aggregate`: the optional
     ``repro.comm`` codec compresses each worker's message (sketch codecs
     feed FA's Gram path directly; biased codecs run through error
     feedback), then the rule aggregates.  FA runs in Gram space (the flat
     (W, n) matrix is never materialized).  With ``sharded_agg`` the
     gradient stack is constrained into coordinate shards straight off the
     backward pass (``repro.dist.sharding.shard_grad_stack`` — no
     device-0 hop) and aggregation runs mesh-native
     (:mod:`repro.dist.sharded`): partial-Gram psum, replicated weight
     solve, shard-local combine.
  4. **Update** — ``repro.optim`` transform + ``apply_updates``.

With a non-trivial ``tc.faults`` schedule (:mod:`repro.dist.membership`)
the step additionally computes the round's active-worker mask *in-graph*
from the step index and threads it through the compression + aggregation
stage: every rule operates on the dynamic worker subset (masked Gram rows
/ masked leaves), absent workers ship no bits and keep their EF memory
frozen, and membership changes never recompile (the mask is a traced
value; all shapes stay (W, ...)).

When the configured codec needs error feedback (``tc.comm.wants_ef``) the
step carries the per-worker EF memory explicitly: its signature becomes
``step(params, opt_state, batch, rng, step_idx, ef)`` returning
``(params, opt_state, metrics, ef)``, with ``ef`` initialized by
``repro.comm.init_ef(params, workers)``.  Without EF the signature is the
classic 5-in / 3-out form, unchanged from the uncompressed path.

Metrics: ``loss`` (mean over workers, pre-attack — honest telemetry),
``lr``, ``grad_global_norm`` (of the aggregated update direction),
``fa_weights`` (the (W,) raw combination weights c — the paper's worker
"value" signal), ``worker_influence`` (|c_i| * ||g_i|| normalized to
sum 1: each worker's share of the aggregated update's mass.  Raw c is the
right paper-faithful quantity but misleading under degenerate norms — a
zero-gradient Byzantine worker gets a huge c yet contributes nothing —
so the Byzantine-dominance tests assert on influence), and
``comm_bits`` / ``comm_ratio`` (bits shipped worker->server this step per
the codec's declared cost model, and the fp32-dense ratio).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.comm.compressors import CommConfig
from repro.core import attacks
from repro.dist.aggregation import AggregatorConfig, compressed_aggregate
from repro.dist.membership import FaultSchedule, membership_at
from repro.dist.sharding import shard_grad_stack
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import Optimizer, apply_updates

__all__ = ["TrainConfig", "init_train_state", "build_train_step",
           "global_norm"]


@dataclass(frozen=True)
class TrainConfig:
    """Distributed-step settings orthogonal to the model config."""

    aggregator: AggregatorConfig = AggregatorConfig()
    attack: str = "none"              # repro.core.attacks registry name
    attack_f: int = 0                 # Byzantine worker count (first f)
    microbatch_splits: int = 1        # grad-accumulation splits per worker
    attn_impl: str = "xla"            # 'xla' (host / dry-run) | 'pallas' (TPU)
    comm: CommConfig = CommConfig()   # worker->server compression (repro.comm)
    faults: FaultSchedule = FaultSchedule()  # worker churn (dist.membership)
    sharded_agg: bool = False         # mesh-sharded aggregation (dist.sharded):
                                      # worker grads go coordinate-sharded by
                                      # construction — partial-Gram psum, no
                                      # device-0 hop, no full (W, n) stack


def init_train_state(key, cfg: ModelConfig, opt: Optimizer):
    """Initialize one model replica's training state.

    Args:
      key: PRNG key for parameter init.
      cfg: the model config.
      opt: the ``repro.optim`` optimizer whose state is initialized.
    Returns:
      ``(params, opt_state)``.  When the train config enables a codec with
      error feedback, the per-worker EF memory is a *third*, separately
      initialized piece of state: ``repro.comm.init_ef(params, workers)``.
    """
    params = transformer.init_params(key, cfg)
    return params, opt.init(params)


def global_norm(tree) -> jnp.ndarray:
    """L2 norm over every leaf of a pytree (fp32)."""
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def build_train_step(cfg: ModelConfig, tc: TrainConfig, opt: Optimizer,
                     sched, *, grad_shardings=None, param_shardings=None):
    """Build the jit-able distributed train step.

    Args:
      cfg: model config (forward/backward definition).
      tc: distributed-step config — aggregator, attack, microbatching, and
        the worker->server compression codec.
      opt: ``repro.optim`` optimizer.
      sched: maps the int32 step index to a learning rate.
      grad_shardings: optional explicit sharding for the worker-major
        gradient pytree (the dry-run passes GSPMD-propagated layouts;
        ``None`` lets XLA choose).
      param_shardings: same, for the updated parameters.
    Returns:
      ``step(params, opt_state, batch, rng, step_idx)`` returning
      ``(new_params, new_opt_state, metrics)`` — unless the codec carries
      error feedback (``tc.comm.wants_ef``), in which case the EF memory is
      an explicit extra carry: ``step(params, opt_state, batch, rng,
      step_idx, ef)`` returning ``(new_params, new_opt_state, metrics,
      new_ef)``, with ``ef`` from ``repro.comm.init_ef(params, workers)``.
    """

    def loss_fn(params, wb):
        return transformer.forward(params, wb, cfg, attn_impl=tc.attn_impl)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def worker_grad(params, wb):
        """Gradient + metrics for ONE worker's (B, ...) batch."""
        k = tc.microbatch_splits
        if k <= 1:
            (_, metrics), g = grad_fn(params, wb)
            return g, metrics
        B = jax.tree.leaves(wb)[0].shape[0]
        if B % k != 0:
            raise ValueError(
                f"microbatch_splits={k} must divide the per-worker batch "
                f"size B={B} (grad accumulation splits the batch into k "
                "equal sequential micro-batches)")
        mb = jax.tree.map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), wb)
        m_shapes = jax.eval_shape(
            lambda p, b: loss_fn(p, b)[1], params,
            jax.tree.map(lambda x: x[0], mb))

        def accum(carry, b):
            acc_g, acc_m = carry
            (_, m), g = grad_fn(params, b)
            return (jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                 acc_g, g),
                    jax.tree.map(jnp.add, acc_m, m)), None

        zeros = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params),
                 jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              m_shapes))
        (g, m), _ = jax.lax.scan(accum, zeros, mb)
        inv = 1.0 / k
        # Accumulation stays fp32; the *output* matches the k<=1 path's
        # param-dtype gradients so the aggregator and comm_bits accounting
        # see the same inputs regardless of k.
        return (jax.tree.map(lambda t, p: (t * inv).astype(p.dtype),
                             g, params),
                jax.tree.map(lambda t: t * inv, m))

    def core(params, opt_state, batch, rng, step_idx, ef):
        grads, metrics_w = jax.vmap(worker_grad, in_axes=(None, 0))(
            params, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)

        if tc.attack != "none" and tc.attack_f > 0:
            grads = attacks.apply_attack_tree(tc.attack, grads, rng,
                                              tc.attack_f)

        W = jax.tree.leaves(grads)[0].shape[0]
        if tc.faults.is_trivial:
            mem, mask = None, None
        else:
            # Membership is a pure jnp function of the traced step index:
            # the same compiled program serves every worker subset.
            mem = membership_at(tc.faults, step_idx, W)
            mask = mem.active.astype(jnp.float32)

        if tc.sharded_agg:
            # Sharded by construction: GSPMD redistributes the per-worker
            # gradients straight into the coordinate-shard layout the
            # sharded aggregation consumes — the (W, n) stack never
            # gathers onto one device on its way to the aggregator.
            grads = shard_grad_stack(grads)

        d, agg_aux, new_ef = compressed_aggregate(
            grads, tc.aggregator, tc.comm, ef, mask=mask,
            sharded=tc.sharded_agg or None)

        lr = sched(step_idx)
        updates, new_opt_state = opt.update(d, opt_state, params, lr)
        new_params = apply_updates(params, updates)
        if param_shardings is not None:
            new_params = jax.lax.with_sharding_constraint(new_params,
                                                          param_shardings)

        c = agg_aux["weights"].astype(jnp.float32)
        worker_norms = jnp.sqrt(sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)),
                    axis=tuple(range(1, l.ndim)))
            for l in jax.tree.leaves(grads)))
        influence = jnp.abs(c) * worker_norms
        influence = influence / jnp.maximum(jnp.sum(influence), 1e-20)

        if mask is None:
            metrics = {k: jnp.mean(v) for k, v in metrics_w.items()}
        else:
            # honest telemetry: absent workers' slots hold garbage — the
            # per-worker metric means cover the active subset only.
            wa = jnp.maximum(jnp.sum(mask), 1.0)
            metrics = {
                k: jnp.sum(v * mask.reshape((W,) + (1,) * (v.ndim - 1)))
                / (wa * (v.size // W))
                for k, v in metrics_w.items()}
        metrics["lr"] = lr
        metrics["grad_global_norm"] = global_norm(d)
        metrics["fa_weights"] = c
        metrics["worker_influence"] = influence
        metrics["comm_bits"] = agg_aux["comm_bits"]
        metrics["comm_ratio"] = agg_aux["comm_ratio"]
        if mem is not None:
            metrics["active_workers"] = jnp.sum(mem.active.astype(jnp.int32))
            metrics["worker_staleness"] = mem.staleness
        return new_params, new_opt_state, metrics, new_ef

    if tc.comm.wants_ef:
        return core           # ef-carrying signature, 6-in / 4-out

    def step(params, opt_state, batch, rng, step_idx):
        new_params, new_opt_state, metrics, _ = core(
            params, opt_state, batch, rng, step_idx, None)
        return new_params, new_opt_state, metrics

    return step
