"""Pallas TPU kernels for the compute hot-spots of the Flag Aggregator stack.

The paper's per-iteration hot spot is the SVD/Gram of the n x p gradient
matrix (their Sec. 4 complexity note); our Gram-space reformulation reduces
the n-scale work to three memory-bound streaming ops, each implemented as a
Pallas kernel with explicit BlockSpec VMEM tiling:

  gram/          K = G^T G        -- blocked tall-skinny matmul, fp32 VMEM acc
  weighted_sum/  d = G @ c        -- fused weighted combine of worker gradients
  coord_stats/   median/trimmed/  -- odd-even-transposition sort network over
                 meamed/phocas      the (tiny) worker axis, blocked over n
  flash_attn/    online-softmax attention (serving path of the dense archs)

Each kernel ships ``ops.py`` (jit'd public wrapper; ``interpret=`` defaults
to True off-TPU so the same code path runs in CI) and ``ref.py`` (pure-jnp
oracle).  ``tests/test_kernels_*.py`` sweep shapes and dtypes asserting
allclose against the oracle.
"""
