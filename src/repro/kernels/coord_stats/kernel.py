"""Coordinate-wise robust statistics over the worker axis, blocked over n.

These are the O(n*p) memory-bound inner loops of the coordinate-wise
baseline aggregators (median / trimmed-mean / MeaMed / Phocas).  The sort
that dominates them runs over the *worker* axis, which is tiny (p <= 64) and
static — so instead of ``lax.sort`` (unsupported inside Pallas TPU kernels)
we unroll an **odd-even transposition sorting network**: p rounds of
vectorized compare-exchange on (p, block_n) VMEM tiles.  Each
compare-exchange is a min/max pair on full lanes, i.e. pure VPU work, and
the network depth is p — for p = 16..64 the kernel stays comfortably
memory-bound, which is the roofline-optimal regime for these ops.

Key-value variants (MeaMed/Phocas need "k values nearest a center") carry
the payload through the network with ``where`` on the swap predicate.

Worker-axis padding: p is padded to the fp32 sublane multiple (8) with
+inf sentinel keys, which sort to the top and are never touched by the
statistics (they all index < p).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sort_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Odd-even transposition sort along axis 0 (ascending). Static p."""
    p = x.shape[0]
    for rnd in range(p):
        start = rnd % 2
        for i in range(start, p - 1, 2):
            lo = jnp.minimum(x[i], x[i + 1])
            hi = jnp.maximum(x[i], x[i + 1])
            x = x.at[i].set(lo).at[i + 1].set(hi)
    return x


def _sort_rows_kv(k: jnp.ndarray, v: jnp.ndarray):
    """Sort rows of k ascending, permuting payload v identically."""
    p = k.shape[0]
    for rnd in range(p):
        start = rnd % 2
        for i in range(start, p - 1, 2):
            swap = k[i] > k[i + 1]
            k_lo = jnp.where(swap, k[i + 1], k[i])
            k_hi = jnp.where(swap, k[i], k[i + 1])
            v_lo = jnp.where(swap, v[i + 1], v[i])
            v_hi = jnp.where(swap, v[i], v[i + 1])
            k = k.at[i].set(k_lo).at[i + 1].set(k_hi)
            v = v.at[i].set(v_lo).at[i + 1].set(v_hi)
    return k, v


def _median_from_sorted(s: jnp.ndarray, p: int) -> jnp.ndarray:
    if p % 2 == 1:
        return s[(p - 1) // 2]
    return 0.5 * (s[p // 2 - 1] + s[p // 2])


def _make_kernel(op: str, p: int, f: int):
    def kernel(g_ref, out_ref):
        g = g_ref[...].astype(jnp.float32)        # (p_pad, block_n)
        s = _sort_rows(g)
        if op == "median":
            r = _median_from_sorted(s, p)
        elif op == "trimmed_mean":
            r = jnp.mean(s[f:p - f], axis=0)
        elif op in ("meamed", "phocas"):
            if op == "meamed":
                center = _median_from_sorted(s, p)
            else:
                center = jnp.mean(s[f:p - f], axis=0)
            dist = jnp.abs(g - center[None, :])    # +inf rows stay +inf
            _, vals = _sort_rows_kv(dist, g)
            r = jnp.mean(vals[:p - f], axis=0)
        else:
            raise ValueError(op)
        out_ref[...] = r[None, :].astype(out_ref.dtype)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("op", "f", "block_n", "interpret"))
def coord_stats_pallas(Gw: jnp.ndarray, *, op: str, f: int = 1,
                       block_n: int = 2048, interpret: bool = True):
    """Coordinate-wise robust stat over workers.  Gw: (p, n) -> (n,)."""
    p, n = Gw.shape
    p_pad = -(-p // 8) * 8
    n_pad = -(-n // block_n) * block_n
    inf = jnp.asarray(jnp.finfo(jnp.float32).max, Gw.dtype)
    Gp = jnp.full((p_pad, n_pad), inf, Gw.dtype).at[:p, :n].set(Gw)

    out = pl.pallas_call(
        _make_kernel(op, p, f),
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((p_pad, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        interpret=interpret,
    )(Gp)
    return out[0, :n]
