"""Coordinate-wise robust statistics over the worker axis, streamed over n.

These are the O(n*p) memory-bound inner loops of the coordinate-wise
baseline aggregators (median / trimmed-mean / MeaMed / Phocas).  The sort
that dominates them runs over the *worker* axis, which is tiny (p <= 64) and
static — so instead of ``lax.sort`` (unsupported inside Pallas TPU kernels,
and scalar-comparator-slow on XLA:CPU) we unroll an **odd-even transposition
sorting network**: p rounds of vectorized compare-exchange on
(p, block_n) VMEM tiles.  Each compare-exchange is a min/max pair on full
lanes, i.e. pure VPU work, and the network depth is p — for p = 16..64 the
kernel stays comfortably memory-bound, which is the roofline-optimal regime
for these ops.

The coordinate stream is chunked with the *same* static plan the fused tree
Gram uses (:func:`repro.kernels.gram.ref.chunk_schedule`, stride 1 — order
statistics must see every coordinate), so the two production kernels share
one grid/padding convention.

Key-value variants (MeaMed/Phocas need "k values nearest a center") carry
the payload through the network with ``where`` on the swap predicate; the
strict ``>`` swap keeps the network stable, matching ``jnp.argsort``'s
stable tie-breaking in the oracles.

**Masked variants** take a (p,) active-worker membership mask (the
:mod:`repro.dist.membership` convention): inactive rows are pushed to the
+sentinel before the network, so they sort to the top and every order
statistic is computed at *traced* positions derived from the active count
W_a = sum(mask) — dynamic membership never changes a shape, so the same
compiled kernel serves every subset.  Row selection at a traced index is a
broadcasted-iota compare + masked row-sum (no dynamic gather on the
sublane axis).

Worker-axis padding: p is padded to the fp32 sublane multiple (8) with
sentinel keys, which sort to the top and are never touched by the
statistics (unmasked: all indices < p; masked: pad rows carry mask 0).

Two (W, W)-sized *distance-selection* kernels live here too:
:func:`krum_scores_pallas` (sum of the k smallest off-diagonal distances
per worker) and :func:`bulyan_select_pallas` (Bulyan's theta-round
recursive Multi-Krum selection, all rounds fused into one kernel via a
``fori_loop`` carrying the availability mask in VMEM — one dispatch
instead of theta sorts).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gram.ref import chunk_schedule

# Sentinel pushed into padded / inactive rows.  finfo.max rather than inf so
# |sentinel - center| stays well-ordered even when the center itself is
# garbage (all-inactive columns), and mirrors the pre-streaming kernel.
_SENTINEL = float(jnp.finfo(jnp.float32).max)


def _pair_roles(shape, start: int):
    """(left, right) row-role masks for one odd-even round.

    Round parity ``start`` pairs rows (i, i+1) for i in
    range(start, P - 1, 2); ``left`` marks the lower row of each pair,
    ``right`` the upper.  Whole-array masks keep each round a handful of
    vector ops — a per-element ``.at[i].set`` formulation traces O(P^2)
    dynamic-update-slices and takes XLA minutes to compile at P = 64.
    """
    P = shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    left = ((row - start) % 2 == 0) & (row >= start) & (row < P - 1)
    right = ((row - start) % 2 == 1) & (row >= start + 1)
    return left, right


def _one_round(x: jnp.ndarray, start: int) -> jnp.ndarray:
    """One fully-vectorized compare-exchange round: every row sees both
    neighbours via roll, then keeps min/max according to its pair role
    (the wrapped neighbour is never selected — the role masks exclude the
    edge rows)."""
    left, right = _pair_roles(x.shape, start)
    up = jnp.roll(x, -1, axis=0)           # row i sees x[i + 1]
    down = jnp.roll(x, 1, axis=0)          # row i sees x[i - 1]
    return jnp.where(left, jnp.minimum(x, up),
                     jnp.where(right, jnp.maximum(x, down), x))


def _sort_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Odd-even transposition sort along axis 0 (ascending).

    P rounds total, rolled into a ``fori_loop`` over (even, odd) round
    pairs so the traced program stays constant-size in P (P is always
    even here — padded to the sublane multiple).
    """
    P = x.shape[0]
    return jax.lax.fori_loop(
        0, P // 2, lambda _, y: _one_round(_one_round(y, 0), 1), x)


def _kv_round(k: jnp.ndarray, v: jnp.ndarray, start: int):
    left, right = _pair_roles(k.shape, start)
    ku, kd = jnp.roll(k, -1, axis=0), jnp.roll(k, 1, axis=0)
    vu, vd = jnp.roll(v, -1, axis=0), jnp.roll(v, 1, axis=0)
    swap_l = left & (k > ku)               # lower row takes the pair min
    swap_r = right & (kd > k)              # upper row takes the pair max
    return (jnp.where(swap_l, ku, jnp.where(swap_r, kd, k)),
            jnp.where(swap_l, vu, jnp.where(swap_r, vd, v)))


def _sort_rows_kv(k: jnp.ndarray, v: jnp.ndarray):
    """Sort rows of k ascending, permuting payload v identically (stable:
    strict-``>`` swaps preserve worker order on ties, like jnp.argsort)."""
    P = k.shape[0]

    def pair(_, kv):
        kv = _kv_round(*kv, 0)
        return _kv_round(*kv, 1)

    return jax.lax.fori_loop(0, P // 2, pair, (k, v))


def _median_from_sorted(s: jnp.ndarray, p: int) -> jnp.ndarray:
    if p % 2 == 1:
        return s[(p - 1) // 2]
    return 0.5 * (s[p // 2 - 1] + s[p // 2])


def _row_at(s: jnp.ndarray, idx) -> jnp.ndarray:
    """s[idx] for a *traced* row index: iota compare + masked row-sum."""
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    return jnp.sum(jnp.where(rows == idx, s, 0.0), axis=0)


# ---------------------------------------------------------------------------
# coordinate-stat kernels (grid streams over n)
# ---------------------------------------------------------------------------

def _make_kernel(op: str, p: int, f: int):
    """Unmasked kernel body: static p, statically clamped f."""
    kt = min(f, (p - 1) // 2)                  # trim width (both sides)
    ka = max(p - f, 1)                         # "k nearest center" count

    def kernel(g_ref, out_ref):
        g = g_ref[...].astype(jnp.float32)        # (p_pad, block_n)
        s = _sort_rows(g)
        if op == "median":
            r = _median_from_sorted(s, p)
        elif op == "trimmed_mean":
            r = jnp.mean(s[kt:p - kt], axis=0)
        elif op in ("meamed", "phocas"):
            if op == "meamed":
                center = _median_from_sorted(s, p)
            else:
                center = jnp.mean(s[kt:p - kt], axis=0)
            dist = jnp.abs(g - center[None, :])    # sentinel rows stay huge
            _, vals = _sort_rows_kv(dist, g)
            r = jnp.mean(vals[:ka], axis=0)
        else:
            raise ValueError(op)
        out_ref[...] = r[None, :].astype(out_ref.dtype)
    return kernel


def _make_masked_kernel(op: str, p: int, f: int):
    """Masked kernel body: order statistics at traced positions.

    Mirrors the ``masked_*`` functions in :mod:`repro.core.aggregators`
    exactly: W_a = max(sum(mask), 1) is traced, inactive rows carry the
    sentinel, and every index/count derives from W_a so the same compiled
    kernel serves every membership subset.
    """

    def kernel(g_ref, m_ref, out_ref):
        g = g_ref[...].astype(jnp.float32)        # (p_pad, block_n)
        m = m_ref[...].astype(jnp.float32)        # (p_pad, 1)
        active = m > 0.0                          # pad rows carry mask 0
        wa = jnp.maximum(jnp.sum(m.astype(jnp.int32)), 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, g.shape, 0)
        s = _sort_rows(jnp.where(active, g, _SENTINEL))

        def masked_median():
            return 0.5 * (_row_at(s, (wa - 1) // 2) + _row_at(s, wa // 2))

        def masked_trimmed():
            kt = jnp.minimum(f, (wa - 1) // 2)
            sel = (rows >= kt) & (rows < wa - kt)
            return (jnp.sum(jnp.where(sel, s, 0.0), axis=0)
                    / jnp.maximum(wa - 2 * kt, 1).astype(jnp.float32))

        if op == "median":
            r = masked_median()
        elif op == "trimmed_mean":
            r = masked_trimmed()
        elif op in ("meamed", "phocas"):
            center = masked_median() if op == "meamed" else masked_trimmed()
            dist = jnp.where(active, jnp.abs(g - center[None, :]), _SENTINEL)
            _, vals = _sort_rows_kv(dist, g)
            ka = jnp.maximum(wa - f, 1)
            r = (jnp.sum(jnp.where(rows < ka, vals, 0.0), axis=0)
                 / ka.astype(jnp.float32))
        else:
            raise ValueError(op)
        out_ref[...] = r[None, :].astype(out_ref.dtype)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("op", "f", "block_n", "interpret"))
def coord_stats_pallas(Gw: jnp.ndarray, mask: jnp.ndarray | None = None, *,
                       op: str, f: int = 1, block_n: int = 2048,
                       interpret: bool = True):
    """Coordinate-wise robust stat over workers.  Gw: (p, n) -> (n,) fp32.

    Args:
      Gw: worker-major (p, n) gradient matrix (fp32 or bf16; the kernel
        upcasts tiles to fp32 on load).
      mask: optional (p,) active-worker membership (bool or 0/1 float,
        traced).  With a mask the dynamic-order-statistic kernel runs and
        the result equals the ``masked_*`` reference on the same mask.
      op: ``median`` | ``trimmed_mean`` | ``meamed`` | ``phocas``.
      f: assumed Byzantine count (trim width / closest-count offset),
        clamped exactly as the references clamp it.
      block_n: coordinate chunk width; the grid follows the shared
        :func:`repro.kernels.gram.ref.chunk_schedule` plan at stride 1.
      interpret: run the Pallas interpreter (CPU) instead of the TPU
        lowering.
    """
    p, n = Gw.shape
    p_pad = -(-p // 8) * 8
    kept, n_pad, _ = chunk_schedule(n, block_n, 1)
    sent = jnp.asarray(_SENTINEL, Gw.dtype)
    Gp = jnp.full((p_pad, n_pad), sent, Gw.dtype).at[:p, :n].set(Gw)

    if mask is None:
        out = pl.pallas_call(
            _make_kernel(op, p, f),
            grid=(kept,),
            in_specs=[pl.BlockSpec((p_pad, block_n), lambda i: (0, i))],
            out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
            interpret=interpret,
        )(Gp)
        return out[0, :n]

    mp = (jnp.zeros((p_pad, 1), jnp.float32)
          .at[:p, 0].set(mask.astype(jnp.float32)))
    out = pl.pallas_call(
        _make_masked_kernel(op, p, f),
        grid=(kept,),
        in_specs=[pl.BlockSpec((p_pad, block_n), lambda i: (0, i)),
                  pl.BlockSpec((p_pad, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        interpret=interpret,
    )(Gp, mp)
    return out[0, :n]


# ---------------------------------------------------------------------------
# (W, W) distance-selection kernels (Krum / Bulyan)
# ---------------------------------------------------------------------------

def _pad_d2(D2: jnp.ndarray):
    """(p, p) -> zero-padded (p_pad8, p_pad128) fp32 tile (masked in-kernel)."""
    p = D2.shape[0]
    pr = -(-p // 8) * 8
    pc = max(128, -(-p // 128) * 128)
    return jnp.zeros((pr, pc), jnp.float32).at[:p, :p].set(
        D2.astype(jnp.float32))


def _make_krum_kernel(p: int, f: int):
    k = max(p - f - 2, 1)

    def kernel(d_ref, out_ref):
        x = d_ref[...]                                   # (pr, pc) fp32
        rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        # Self-distances and padding sort to the top, never into the k sum
        # (k <= p - 3 < p - 1 real entries per column).  Finite sentinel,
        # not inf: the sorting network's max/min compares stay NaN-free
        # and KSENTINEL holds.
        x = jnp.where((rows == cols) | (rows >= p) | (cols >= p),
                      _SENTINEL, x)
        s = _sort_rows(x)
        out_ref[...] = jnp.sum(jnp.where(rows < k, s, 0.0),
                               axis=0)[None, :]
    return kernel


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def krum_scores_pallas(D2: jnp.ndarray, *, f: int = 1,
                       interpret: bool = True) -> jnp.ndarray:
    """Krum score per worker from (p, p) squared distances -> (p,) fp32.

    Each worker's score is the sum of its p - f - 2 smallest distances to
    the *other* workers, computed with the same sorting network as the
    coordinate kernels (distances sorted ascending per column — D2 is
    symmetric — then a prefix sum of the first k rows).
    """
    p = D2.shape[0]
    out = pl.pallas_call(
        _make_krum_kernel(p, f),
        grid=(1,),
        in_specs=[pl.BlockSpec(_pad_d2(D2).shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, _pad_d2(D2).shape[1]), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, _pad_d2(D2).shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(_pad_d2(D2))
    return out[0, :p]


def _make_bulyan_kernel(p: int, f: int):
    theta = max(p - 2 * f, 1)
    k = max(p - f - 2, 1)

    def kernel(d_ref, out_ref):
        x0 = d_ref[...]                                  # (pr, pc) fp32
        pr, pc = x0.shape
        rows = jax.lax.broadcasted_iota(jnp.int32, (pr, pc), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (pr, pc), 1)
        valid = (rows < p) & (cols < p) & (rows != cols)
        # Same sentinel contract as aggregators.bulyan_select: masked-out
        # pairs contribute a finite `big` every round (same count per
        # column), so ordering is decided by the real part.
        big = 4.0 * jnp.max(jnp.where(valid, x0, 0.0)) + 1.0
        row_id = jax.lax.broadcasted_iota(jnp.int32, (pr, 1), 0)
        col_id = jax.lax.broadcasted_iota(jnp.int32, (1, pc), 1)

        def body(r, carry):
            avail_r, avail_c, order = carry
            pair = avail_r & avail_c                     # (pr, pc)
            # Finite sentinel (not inf) in both spots: invalid entries
            # never reach the first-k sum of a real column (p - 1 finite
            # entries >= k there), and unavailable columns only need to
            # lose every argmin against finite real scores.
            x = jnp.where(valid, jnp.where(pair, x0, big), _SENTINEL)
            s = _sort_rows(x)
            sc = jnp.sum(jnp.where(rows < k, s, 0.0), axis=0)[None, :]
            sc = jnp.where(avail_c, sc, _SENTINEL)
            pick = jnp.argmin(sc[0]).astype(jnp.int32)
            order = jnp.where(col_id == pick, r, order)
            return (avail_r & (row_id != pick),
                    avail_c & (col_id != pick), order)

        carry0 = (row_id < p, col_id < p,
                  jnp.full((1, pc), theta, jnp.int32))
        _, _, order = jax.lax.fori_loop(0, theta, body, carry0)
        out_ref[...] = order
    return kernel


@functools.partial(jax.jit, static_argnames=("f", "interpret"))
def bulyan_select_pallas(D2: jnp.ndarray, *, f: int = 1,
                         interpret: bool = True) -> jnp.ndarray:
    """Bulyan's recursive Multi-Krum selection, fused into ONE kernel.

    All theta = max(p - 2f, 1) selection rounds run inside a single
    ``pallas_call`` (a ``fori_loop`` carrying the availability mask in
    VMEM), instead of theta separate score/sort dispatches.  The kernel
    emits the *selection order* per worker (round index, or theta for
    unselected — no dynamic stores needed); the wrapper converts it to the
    (theta,) pick list of :func:`repro.core.aggregators.bulyan_select`.
    """
    p = D2.shape[0]
    theta = max(p - 2 * f, 1)
    Dp = _pad_d2(D2)
    order = pl.pallas_call(
        _make_bulyan_kernel(p, f),
        grid=(1,),
        in_specs=[pl.BlockSpec(Dp.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, Dp.shape[1]), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, Dp.shape[1]), jnp.int32),
        interpret=interpret,
    )(Dp)
    # ascending selection-round order; unselected carry the theta sentinel
    return jnp.argsort(order[0, :p], stable=True)[:theta]
