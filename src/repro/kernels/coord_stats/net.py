"""The coordinate selection network lowered through plain XLA.

Same algorithm as :mod:`repro.kernels.coord_stats.kernel` — a W-wide
odd-even transposition network per coordinate, stable key-value variant for
the mean-around ops, sentinel rows + traced active counts for masked
membership — but expressed as *unstacked* per-row elementwise ops instead
of a ``pallas_call``.

Why this exists: on TPU the Pallas kernel keeps each (W, block_n) tile in
VMEM across all W rounds, so the whole network costs one HBM read — that's
the roofline-optimal lowering there.  On CPU the Pallas interpreter
executes the grid/loop machinery op by op and each round round-trips
memory (~70 ms at p = 15, n = 1e5).  Handing XLA the same network as a flat
graph of ``minimum``/``maximum``/``where`` on (n,) rows lets its loop
fusion collapse **all rounds into a single pass over the coordinates**:
median lands at ~2x the cost of ``mean`` — against ~100 ms for the
``jnp.sort``-based reference, whose scalar comparator XLA:CPU cannot
vectorize.  This is what ``impl="pallas"`` dispatches to off-TPU
(``impl="pallas_interpret"`` still runs the real Pallas interpreter, which
is how CI exercises the kernel path on CPU).

The network is unrolled per (p, f, op), so tracing is O(p^2) compare
exchanges — fine for the W <= 64 regime these rules target (the dispatch
layer never routes larger worker counts here).

Masked semantics are identical to ``masked_*`` in
:mod:`repro.core.aggregators` and to the masked Pallas kernel: inactive
rows carry the +sentinel, every order statistic derives from the traced
active count, and ``mask[i]`` enters each row as a 0-d predicate so
membership changes never retrace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_SENTINEL = float(jnp.finfo(jnp.float32).max)


def _sort_net(rows: list) -> list:
    """Odd-even transposition network over a list of (n,) rows (ascending)."""
    p = len(rows)
    rows = list(rows)
    for rnd in range(p):
        for i in range(rnd % 2, p - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return rows


def _sort_net_kv(ks: list, vs: list):
    """Key-sort carrying payload (stable: strict-``>`` swap predicate)."""
    p = len(ks)
    ks, vs = list(ks), list(vs)
    for rnd in range(p):
        for i in range(rnd % 2, p - 1, 2):
            swap = ks[i] > ks[i + 1]
            ks[i], ks[i + 1] = (jnp.where(swap, ks[i + 1], ks[i]),
                                jnp.where(swap, ks[i], ks[i + 1]))
            vs[i], vs[i + 1] = (jnp.where(swap, vs[i + 1], vs[i]),
                                jnp.where(swap, vs[i], vs[i + 1]))
    return ks, vs


def _row_at(rows: list, idx) -> jnp.ndarray:
    """rows[idx] at a traced index: predicated sum over the unrolled rows."""
    return sum(jnp.where(jnp.asarray(i) == idx, r, 0.0)
               for i, r in enumerate(rows))


@functools.partial(jax.jit, static_argnames=("op", "f"))
def coord_stats_net(Gw: jnp.ndarray, mask: jnp.ndarray | None = None, *,
                    op: str, f: int = 1) -> jnp.ndarray:
    """Network-lowered coordinate stat.  Gw: (p, n) -> (n,) fp32.

    Selection-identical to :func:`repro.kernels.coord_stats.kernel.
    coord_stats_pallas` (same network, same stable tie-breaking, same
    masked sentinel construction); the trimmed/mean-around reductions may
    associate fp32 sums differently, so outputs agree to ~1e-6 relative
    rather than bitwise.
    """
    p = Gw.shape[0]
    x = Gw.astype(jnp.float32)
    rows = [x[i] for i in range(p)]

    if mask is None:
        srt = _sort_net(rows)
        if op == "median":
            r = (srt[(p - 1) // 2] if p % 2
                 else 0.5 * (srt[p // 2 - 1] + srt[p // 2]))
        elif op == "trimmed_mean":
            kt = min(f, (p - 1) // 2)
            r = sum(srt[kt:p - kt]) / (p - 2 * kt)
        elif op in ("meamed", "phocas"):
            if op == "meamed":
                center = (srt[(p - 1) // 2] if p % 2
                          else 0.5 * (srt[p // 2 - 1] + srt[p // 2]))
            else:
                kt = min(f, (p - 1) // 2)
                center = sum(srt[kt:p - kt]) / (p - 2 * kt)
            ks = [jnp.abs(row - center) for row in rows]
            _, vs = _sort_net_kv(ks, rows)
            ka = max(p - f, 1)
            r = sum(vs[:ka]) / ka
        else:
            raise ValueError(op)
        return r

    m = mask.astype(jnp.float32)
    active = [m[i] > 0.0 for i in range(p)]            # 0-d predicates
    wa = jnp.maximum(jnp.sum(m.astype(jnp.int32)), 1)
    srt = _sort_net([jnp.where(a, row, _SENTINEL)
                     for a, row in zip(active, rows)])

    def masked_median():
        return 0.5 * (_row_at(srt, (wa - 1) // 2) + _row_at(srt, wa // 2))

    def masked_trimmed():
        kt = jnp.minimum(f, (wa - 1) // 2)
        r = sum(jnp.where((jnp.asarray(i) >= kt) & (jnp.asarray(i) < wa - kt),
                          s, 0.0)
                for i, s in enumerate(srt))
        return r / jnp.maximum(wa - 2 * kt, 1).astype(jnp.float32)

    if op == "median":
        return masked_median()
    if op == "trimmed_mean":
        return masked_trimmed()
    if op in ("meamed", "phocas"):
        center = masked_median() if op == "meamed" else masked_trimmed()
        ks = [jnp.where(a, jnp.abs(row - center), _SENTINEL)
              for a, row in zip(active, rows)]
        _, vs = _sort_net_kv(ks, rows)
        ka = jnp.maximum(wa - f, 1)
        r = sum(jnp.where(jnp.asarray(i) < ka, v, 0.0)
                for i, v in enumerate(vs))
        return r / ka.astype(jnp.float32)
    raise ValueError(op)
