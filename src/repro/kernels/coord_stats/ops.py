"""Public wrappers for the coordinate-wise / selection kernels.

Same ``impl`` convention as :mod:`repro.kernels.gram.ops`:

  - ``"xla"``             — the pure-jnp references (also the oracles),
  - ``"pallas"``          — the selection network: ``pallas_call`` on TPU,
    the fused XLA network lowering (:mod:`.net`) elsewhere — the Pallas
    interpreter cannot fuse the rounds on CPU, the net lowering can (see
    net.py's docstring for the measured gap),
  - ``"pallas_interpret"`` — the true Pallas interpreter everywhere (this
    is how CI exercises the kernel path on CPU).

``coord_stat`` accepts the membership ``mask=`` of the distributed layer;
masked calls route to the dynamic-order-statistic network (or the
``masked_*`` references), so dynamic worker subsets never trigger a
recompile on any path.
"""

from __future__ import annotations

from repro.kernels.coord_stats import ref
from repro.kernels.coord_stats.kernel import (
    bulyan_select_pallas,
    coord_stats_pallas,
    krum_scores_pallas,
)
from repro.kernels.coord_stats.net import coord_stats_net
from repro.kernels.gram.ops import on_tpu

_REFS = {
    "median": lambda Gw, f: ref.median_ref(Gw),
    "trimmed_mean": ref.trimmed_mean_ref,
    "meamed": ref.meamed_ref,
    "phocas": ref.phocas_ref,
}

COORD_OPS = tuple(_REFS)


def _interpret(impl: str) -> bool:
    if impl == "pallas":
        return not on_tpu()
    if impl == "pallas_interpret":
        return True
    raise ValueError(f"unknown impl {impl!r}")


def coord_stat(Gw, *, op: str, f: int = 1, impl: str = "xla",
               block_n: int = 2048, mask=None):
    """Coordinate-wise robust statistic.  Gw: (p, n) -> (n,).

    op: median | trimmed_mean | meamed | phocas.  ``mask`` is an optional
    traced (p,) active-worker membership vector (bool or 0/1).
    """
    if op not in _REFS:
        raise ValueError(f"unknown op {op!r}")
    if impl == "xla":
        if mask is None:
            return _REFS[op](Gw, f)
        from repro.core.aggregators import MASKED_COORDWISE
        return MASKED_COORDWISE[op](Gw, mask, f=f)
    if impl == "pallas" and not on_tpu():
        out = coord_stats_net(Gw, mask, op=op, f=f)
        return out.astype(Gw.dtype)
    out = coord_stats_pallas(Gw, mask, op=op, f=f, block_n=block_n,
                             interpret=_interpret(impl))
    # kernel accumulates and emits fp32; hand back the caller's dtype so
    # the leafwise tree path keeps leaf dtypes like the XLA references do.
    return out.astype(Gw.dtype)


def krum_scores(D2, *, f: int = 1, impl: str = "xla"):
    """Krum score per worker from (p, p) squared distances -> (p,)."""
    if impl == "xla" or (impl == "pallas" and not on_tpu()):
        # the (p, p) selection problem is tiny — off-TPU the jnp reference
        # IS the production lowering; the interpreter is opt-in only.
        from repro.core.aggregators import krum_scores as _ref
        return _ref(D2, f)
    return krum_scores_pallas(D2, f=f, interpret=_interpret(impl))


def bulyan_select(D2, *, f: int = 1, impl: str = "xla"):
    """Bulyan's theta = max(p - 2f, 1) picks, lowest-Krum-score-first."""
    if impl == "xla" or (impl == "pallas" and not on_tpu()):
        from repro.core.aggregators import bulyan_select as _ref
        return _ref(D2, f)
    return bulyan_select_pallas(D2, f=f, interpret=_interpret(impl))
