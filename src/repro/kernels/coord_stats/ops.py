"""Public wrapper for coordinate-wise robust stats (see gram/ops.py)."""

from __future__ import annotations

from repro.kernels.gram.ops import on_tpu
from repro.kernels.coord_stats.kernel import coord_stats_pallas
from repro.kernels.coord_stats import ref

_REFS = {
    "median": lambda Gw, f: ref.median_ref(Gw),
    "trimmed_mean": ref.trimmed_mean_ref,
    "meamed": ref.meamed_ref,
    "phocas": ref.phocas_ref,
}


def coord_stat(Gw, *, op: str, f: int = 1, impl: str = "xla",
               block_n: int = 2048):
    """Coordinate-wise robust statistic. op: median|trimmed_mean|meamed|phocas."""
    if op not in _REFS:
        raise ValueError(f"unknown op {op!r}")
    if impl == "xla":
        return _REFS[op](Gw, f)
    if impl == "pallas":
        return coord_stats_pallas(Gw, op=op, f=f, block_n=block_n,
                                  interpret=not on_tpu())
    if impl == "pallas_interpret":
        return coord_stats_pallas(Gw, op=op, f=f, block_n=block_n,
                                  interpret=True)
    raise ValueError(f"unknown impl {impl!r}")
