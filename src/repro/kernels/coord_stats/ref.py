"""Pure-jnp references for the coordinate-wise robust statistics.

**Single source of truth.**  These are simultaneously

  1. the XLA production path (``impl="xla"`` in
     :func:`repro.kernels.coord_stats.ops.coord_stat`),
  2. the oracles the Pallas selection-network kernel is property-tested
     against, and
  3. the implementations behind the public baseline aggregators —
     :mod:`repro.core.aggregators` imports *these* rather than keeping its
     own copies, so the kernel oracle and the user-facing rule can never
     drift apart (``tests/test_coord_stats.py`` asserts the wiring).

Clamping conventions (shared with the masked variants and the kernel):
``trimmed_mean_ref`` trims ``k = min(f, (p - 1) // 2)`` per side (an
over-aggressive ``f`` degrades to the median-ish middle rather than an
empty slice); ``meamed_ref`` / ``phocas_ref`` keep ``max(p - f, 1)``
values.

This module is deliberately pure ``jax.numpy`` — no Pallas import — so the
``core`` layer can depend on it without pulling kernel machinery into the
baseline aggregators.
"""

from __future__ import annotations

import jax.numpy as jnp


def median_ref(Gw: jnp.ndarray) -> jnp.ndarray:
    """Coordinate-wise median over the worker axis.  Gw: (p, n) -> (n,)."""
    return jnp.median(Gw, axis=0)


def trimmed_mean_ref(Gw: jnp.ndarray, f: int) -> jnp.ndarray:
    """Mean after dropping the k largest and k smallest per coordinate,
    k = min(f, (p - 1) // 2)."""
    p = Gw.shape[0]
    k = min(f, (p - 1) // 2)
    s = jnp.sort(Gw, axis=0)
    return jnp.mean(s[k:p - k], axis=0) if k > 0 else jnp.mean(s, axis=0)


def mean_around_ref(Gw: jnp.ndarray, center: jnp.ndarray,
                    k: int) -> jnp.ndarray:
    """Mean of the k values closest to ``center``, per coordinate.

    Stable argsort on |Gw - center| (ties keep worker order), matching the
    strict-``>`` compare-exchange of the Pallas selection network.
    """
    d = jnp.abs(Gw - center[None, :])
    order = jnp.argsort(d, axis=0)
    gathered = jnp.take_along_axis(Gw, order[:k], axis=0)
    return jnp.mean(gathered, axis=0)


def meamed_ref(Gw: jnp.ndarray, f: int) -> jnp.ndarray:
    """Mean-around-median: mean of the max(p - f, 1) values closest to the
    coordinate-wise median [Xie et al. 2018]."""
    p = Gw.shape[0]
    return mean_around_ref(Gw, median_ref(Gw), max(p - f, 1))


def phocas_ref(Gw: jnp.ndarray, f: int) -> jnp.ndarray:
    """Phocas: mean of the max(p - f, 1) values closest to the trimmed
    mean [Xie et al. 2018]."""
    p = Gw.shape[0]
    return mean_around_ref(Gw, trimmed_mean_ref(Gw, f), max(p - f, 1))
