"""Pure-jnp oracles for the coordinate-wise robust statistics kernel."""

from __future__ import annotations

import jax.numpy as jnp


def median_ref(Gw: jnp.ndarray) -> jnp.ndarray:
    """Coordinate-wise median over the worker axis.  Gw: (p, n) -> (n,)."""
    return jnp.median(Gw, axis=0)


def trimmed_mean_ref(Gw: jnp.ndarray, f: int) -> jnp.ndarray:
    """Mean after dropping the f largest and f smallest per coordinate."""
    p = Gw.shape[0]
    s = jnp.sort(Gw, axis=0)
    return jnp.mean(s[f:p - f], axis=0)


def meamed_ref(Gw: jnp.ndarray, f: int) -> jnp.ndarray:
    """Mean of the p-f values closest to the coordinate-wise median."""
    p = Gw.shape[0]
    med = jnp.median(Gw, axis=0)
    d = jnp.abs(Gw - med[None, :])
    order = jnp.argsort(d, axis=0)
    return jnp.mean(jnp.take_along_axis(Gw, order[:p - f], axis=0), axis=0)


def phocas_ref(Gw: jnp.ndarray, f: int) -> jnp.ndarray:
    """Mean of the p-f values closest to the trimmed mean."""
    p = Gw.shape[0]
    tm = trimmed_mean_ref(Gw, f)
    d = jnp.abs(Gw - tm[None, :])
    order = jnp.argsort(d, axis=0)
    return jnp.mean(jnp.take_along_axis(Gw, order[:p - f], axis=0), axis=0)
