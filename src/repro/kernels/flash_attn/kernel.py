"""Flash attention (online softmax) Pallas kernel — TPU target.

Grid (batch*heads, n_q_blocks, n_k_blocks); the innermost k axis revisits
the same output block, carrying the running max ``m``, normalizer ``l`` and
unnormalized accumulator in *output* VMEM blocks (constant index_map over
k) — initialized at k==0 and normalized in place at the last k step.  This
is the canonical Pallas reduction idiom and avoids backend-specific scratch.

Numerics: scores are masked with a finite sentinel (NEG = -1e30) and the
probability tile is multiplied by the boolean mask, so fully-masked blocks
contribute exactly zero without -inf/-inf NaNs.  Accumulation is fp32
regardless of input dtype; the MXU contractions use
preferred_element_type=float32.

Supports causal masking and sliding windows (the serving path of the SWA
variants); queries are aligned to the *tail* of the key sequence so the same
kernel serves prefill (sq == sk) and decode (sq == 1, sk == cache length).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale, causal, window, block_q, block_k, seq_q, seq_k):
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (block_q, d)
    k = k_ref[0].astype(jnp.float32)                    # (block_k, d)
    v = v_ref[0].astype(jnp.float32)                    # (block_k, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)

    row = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + (seq_k - seq_q)                               # absolute q position
    col = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = col < seq_k                                  # k-padding
    mask &= row < seq_k                                 # q-padding (tail align)
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[0]                                   # (block_q, 1)
    l_prev = l_ref[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)                     # <= 1, finite
    p = jnp.exp(s - m_cur) * mask.astype(jnp.float32)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = o_ref[0].astype(jnp.float32) * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[0] = m_cur
    l_ref[0] = l_new
    o_ref[0] = acc.astype(o_ref.dtype)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[0]
        o_ref[0] = jnp.where(
            l > 0, o_ref[0].astype(jnp.float32) / jnp.maximum(l, 1e-30), 0.0
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attn_pallas(q, k, v, *, causal: bool = True,
                      window: int | None = None, scale: float | None = None,
                      block_q: int = 128, block_k: int = 128,
                      interpret: bool = True):
    """q: (b, h, sq, d), k/v: (b, h, sk, d) -> (b, h, sq, d)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, max(8, sq))
    block_k = min(block_k, max(8, sk))
    sq_pad = -(-sq // block_q) * block_q
    sk_pad = -(-sk // block_k) * block_k

    def pad(x, s_pad):
        return jnp.zeros((b * h, s_pad, d), x.dtype).at[:, :x.shape[2], :].set(
            x.reshape(b * h, x.shape[2], d))

    qp, kp, vp = pad(q, sq_pad), pad(k, sk_pad), pad(v, sk_pad)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=sq, seq_k=sk)

    o, _, _ = pl.pallas_call(
        kernel,
        grid=(b * h, sq_pad // block_q, sk_pad // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda bh, i, j: (bh, i, 0)),
        ],
        out_shape=[
            # The o block is a cross-step accumulator (the k axis revisits
            # it): it must be fp32 even for bf16 inputs, else every store
            # rounds the running sum (KPRECISION).  Cast once on the way out.
            jax.ShapeDtypeStruct((b * h, sq_pad, d), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return o[:, :sq, :].reshape(b, h, sq, d).astype(q.dtype)
