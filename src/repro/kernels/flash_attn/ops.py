"""Public wrapper for flash attention (see gram/ops.py for the impl knob)."""

from __future__ import annotations

from repro.kernels.flash_attn.kernel import flash_attn_pallas
from repro.kernels.flash_attn.ref import flash_attn_ref
from repro.kernels.gram.ops import on_tpu


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    impl: str = "xla", block_q: int = 128, block_k: int = 128):
    """Multi-head attention. q: (b,h,sq,d), k/v: (b,h,sk,d) -> (b,h,sq,d)."""
    if impl == "xla":
        return flash_attn_ref(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "pallas":
        if not on_tpu():                # production fallback off-TPU
            return flash_attn_ref(q, k, v, causal=causal, window=window,
                                  scale=scale)
        return flash_attn_pallas(q, k, v, causal=causal, window=window,
                                 scale=scale, block_q=block_q, block_k=block_k,
                                 interpret=False)
    if impl == "pallas_interpret":
        return flash_attn_pallas(q, k, v, causal=causal, window=window,
                                 scale=scale, block_q=block_q, block_k=block_k,
                                 interpret=True)
    raise ValueError(f"unknown impl {impl!r}")
