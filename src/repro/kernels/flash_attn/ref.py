"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30


def attention_mask(seq_q: int, seq_k: int, *, causal: bool,
                   window: int | None) -> jnp.ndarray:
    """(seq_q, seq_k) boolean mask.  Query i sits at absolute position
    i + (seq_k - seq_q) (decode convention: queries are the tail)."""
    row = jnp.arange(seq_q)[:, None] + (seq_k - seq_q)
    col = jnp.arange(seq_k)[None, :]
    mask = jnp.ones((seq_q, seq_k), bool)
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    return mask


def flash_attn_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Softmax attention.  q: (b, h, sq, d), k/v: (b, h, sk, d)."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = attention_mask(q.shape[2], k.shape[2], causal=causal, window=window)
    s = jnp.where(mask[None, None], s, NEG)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * mask[None, None]
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(l > 0, p / jnp.maximum(l, 1e-30), 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
