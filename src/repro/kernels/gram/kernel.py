"""Blocked tall-skinny Gram kernel:  K = G^T G,  G in R^{n x p},  p << n.

TPU mapping.  G streams HBM -> VMEM in (block_n, p_pad) tiles; the (p_pad,
p_pad) fp32 accumulator lives in the *output* VMEM block, which every grid
step revisits (index_map is constant) — the canonical Pallas reduction
pattern.  p is padded to the 128-lane width so the MXU sees an aligned
(block_n x 128) @ (128 x block_n)^T contraction; zero padding contributes
zeros to K, removed by the wrapper.

The contraction is issued as  dot(G_blk^T, G_blk)  with
preferred_element_type=float32 so bf16 gradients accumulate in fp32 (bf16
Gram accumulation is one of the §Perf experiments — see ops.gram(precision=...)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(g_ref, k_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        k_ref[...] = jnp.zeros_like(k_ref)

    g = g_ref[...]                                   # (block_n, p_pad)
    k_ref[...] += jax.lax.dot_general(
        g, g,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over n-block
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_pallas(G: jnp.ndarray, *, block_n: int = 1024,
                interpret: bool = True) -> jnp.ndarray:
    """K = G^T G via pallas_call.  G: (n, p); returns (p, p) fp32.

    The wrapper pads n up to a block multiple and p up to the 128-lane
    width; padding rows/cols are zero so they do not perturb K.
    """
    n, p = G.shape
    p_pad = max(128, -(-p // 128) * 128)
    n_pad = -(-n // block_n) * block_n
    Gp = jnp.zeros((n_pad, p_pad), G.dtype).at[:n, :p].set(G)

    K = pl.pallas_call(
        _gram_kernel,
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((block_n, p_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((p_pad, p_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p_pad, p_pad), jnp.float32),
        interpret=interpret,
    )(Gp)
    return K[:p, :p]
