"""Blocked tall-skinny Gram kernels:  K = G^T G,  G in R^{n x p},  p << n.

Two kernels live here:

* :func:`gram_pallas` — the original per-matrix kernel (one ``pallas_call``
  per (n, p) matrix; the *looped* tree path dispatches it once per leaf).
* :func:`tree_gram_pallas` — the fused one-pass tree kernel: the whole
  worker-major gradient row-stack (every leaf concatenated, (W, N)) streams
  through a single ``pallas_call`` as fixed-size (W_pad, block_n) chunks
  into one fp32 accumulator.  ``sketch_stride`` is folded into the index
  map (grid step j reads the chunk at block index j*stride) so the sketch
  never materializes a strided+scaled copy; the wrapper rescales once by
  the exact sampling fraction from :func:`ref.chunk_schedule`.

TPU mapping (both).  Tiles stream HBM -> VMEM; the fp32 accumulator lives
in the *output* VMEM block, which every grid step revisits (index_map is
constant) — the canonical Pallas reduction pattern.  The worker axis is
padded to the 128-lane width once per call; zero padding contributes zeros
to K, removed by the wrapper.  Contractions are issued with
preferred_element_type=float32 so bf16 gradients accumulate in fp32 (bf16
Gram accumulation is one of the §Perf experiments — see ops.gram(precision=...)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gram.ref import chunk_schedule


def _gram_kernel(g_ref, k_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        k_ref[...] = jnp.zeros_like(k_ref)

    g = g_ref[...]                                   # (block_n, p_pad)
    k_ref[...] += jax.lax.dot_general(
        g, g,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over n-block
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_pallas(G: jnp.ndarray, *, block_n: int = 1024,
                interpret: bool = True) -> jnp.ndarray:
    """K = G^T G via pallas_call.  G: (n, p); returns (p, p) fp32.

    The wrapper pads n up to a block multiple and p up to the 128-lane
    width; padding rows/cols are zero so they do not perturb K.
    """
    n, p = G.shape
    p_pad = max(128, -(-p // 128) * 128)
    n_pad = -(-n // block_n) * block_n
    Gp = jnp.zeros((n_pad, p_pad), G.dtype).at[:n, :p].set(G)

    K = pl.pallas_call(
        _gram_kernel,
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((block_n, p_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((p_pad, p_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p_pad, p_pad), jnp.float32),
        interpret=interpret,
    )(Gp)
    return K[:p, :p]


def _tree_gram_kernel(x_ref, k_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        k_ref[...] = jnp.zeros_like(k_ref)

    x = x_ref[...]                                   # (w_pad, block_n)
    k_ref[...] += jax.lax.dot_general(
        x, x,
        dimension_numbers=(((1,), (1,)), ((), ())),  # contract over n-chunk
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("sketch_stride", "block_n",
                                             "interpret"))
def tree_gram_pallas(X: jnp.ndarray, *, sketch_stride: int = 1,
                     block_n: int = 1024,
                     interpret: bool = True) -> jnp.ndarray:
    """One-pass fused Gram:  K = scale * X_S X_S^T in a single pallas_call.

    X: (W, N) worker-major row-stack of every flattened gradient leaf
    (bf16 or fp32).  X_S is the chunk subset of :func:`ref.chunk_schedule`
    — with ``sketch_stride`` > 1 the grid visits every stride-th
    (W_pad, block_n) chunk via the index map, skipping the rest of HBM
    entirely.  Returns (W, W) fp32.
    """
    w, n = X.shape
    w_pad = max(128, -(-w // 128) * 128)
    kept, n_pad, scale = chunk_schedule(n, block_n, sketch_stride)
    Xp = jnp.zeros((w_pad, n_pad), X.dtype).at[:w, :n].set(X)

    stride = max(1, sketch_stride)
    K = pl.pallas_call(
        _tree_gram_kernel,
        grid=(kept,),
        in_specs=[pl.BlockSpec((w_pad, block_n), lambda j: (0, j * stride))],
        out_specs=pl.BlockSpec((w_pad, w_pad), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((w_pad, w_pad), jnp.float32),
        interpret=interpret,
    )(Xp)
    K = K[:w, :w]
    return K * scale if scale != 1.0 else K
