"""Public wrappers for the Gram kernels.

``gram(G)`` is the per-matrix op (one dispatch per leaf — the *looped*
tree path).  ``tree_gram_fused(leaves)`` is the one-pass tree op, one
chunk plan for the whole pytree: on the Pallas backends the flattened
leaves are packed into a single worker-major (W, N) row-stack feeding
exactly ONE ``pallas_call`` (asserted by jaxpr inspection in
``tests/test_gram_solvers.py``); on XLA the same plan is consumed
piecewise (:func:`ref.tree_gram_pieces_ref` — Gram additivity over static
per-leaf ranges, since a pack copy buys XLA nothing).  Both backends
sample the identical coordinate set (:func:`ref.chunk_schedule`), so
``sketch_stride`` means the same thing everywhere: keep every stride-th
block_n-wide chunk, rescale by the exact inverse sampling fraction.

Callers pick the backend via ``impl=``; the distributed aggregator
defaults to ``xla`` so the multi-pod dry-run lowers on the host platform,
and flips to ``pallas`` on real TPU via config.

``impl`` convention (shared by every ``kernels/*/ops.py``):

  - ``"xla"``              — the jnp reference (also the test oracle),
  - ``"pallas"``           — the *production* kernel path: ``pallas_call``
    on TPU, the best available XLA lowering elsewhere.  The interpreter is
    never a production path — it re-executes the grid machinery op by op
    and is orders of magnitude off the roofline on CPU,
  - ``"pallas_interpret"`` — force the true Pallas interpreter everywhere
    (how CI exercises the kernel path on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram.kernel import gram_pallas, tree_gram_pallas
from repro.kernels.gram.ref import gram_ref, tree_gram_pieces_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gram(G, *, impl: str = "xla", block_n: int = 1024):
    """K = G^T G (fp32). impl: 'xla' | 'pallas' | 'pallas_interpret'."""
    if impl == "xla":
        return gram_ref(G)
    if impl == "pallas":
        if on_tpu():
            return gram_pallas(G, block_n=block_n, interpret=False)
        return gram_ref(G)              # production fallback off-TPU
    if impl == "pallas_interpret":
        return gram_pallas(G, block_n=block_n, interpret=True)
    raise ValueError(f"unknown impl {impl!r}")


def pack_leaves(leaves, *, gram_dtype: str = "float32") -> jnp.ndarray:
    """(W, ...) leaves -> one worker-major (W, N) row-stack.

    ``gram_dtype`` != 'float32' down-casts the stack before the matmul
    (bf16-in / fp32-accumulate); otherwise leaves keep their own dtype
    (promoted to a common one only if they disagree).
    """
    if not leaves:
        raise ValueError("pack_leaves: empty leaf list")
    target = (jnp.dtype(gram_dtype) if gram_dtype != "float32"
              else jnp.result_type(*leaves))
    W = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.reshape(W, -1).astype(target) for leaf in leaves], axis=1)


def tree_gram_fused(leaves, *, sketch_stride: int = 1,
                    gram_dtype: str = "float32", impl: str = "xla",
                    block_n: int = 1024) -> jnp.ndarray:
    """One-pass (W, W) fp32 Gram of a whole leaf list — one kernel call.

    Args:
      leaves: worker-major arrays, every leaf shaped ``(W, ...)``.
      sketch_stride: keep every stride-th block_n-wide chunk of the packed
        stack (folded into the kernel index map — no strided copy), with
        the exact inverse-fraction rescale so the diagonal stays unbiased.
      gram_dtype: dtype the packed stack is cast to *before* the
        contraction (accumulation stays fp32).
      impl: 'xla' | 'pallas' | 'pallas_interpret'.
    """
    if impl == "xla" or (impl == "pallas" and not on_tpu()):
        # XLA consumes the identical chunk plan piecewise (Gram
        # additivity) — packing here would only add a (W, n) copy that
        # the dot cannot amortize on CPU; the dispatch-count win the pack
        # buys is a Pallas-only concern.
        if gram_dtype != "float32":
            target = jnp.dtype(gram_dtype)
            leaves = [leaf.astype(target) for leaf in leaves]
        return tree_gram_pieces_ref(leaves, sketch_stride=sketch_stride,
                                    block_n=block_n)
    X = pack_leaves(leaves, gram_dtype=gram_dtype)
    if impl == "pallas":
        return tree_gram_pallas(X, sketch_stride=sketch_stride,
                                block_n=block_n, interpret=False)
    if impl == "pallas_interpret":
        return tree_gram_pallas(X, sketch_stride=sketch_stride,
                                block_n=block_n, interpret=True)
    raise ValueError(f"unknown impl {impl!r}")
