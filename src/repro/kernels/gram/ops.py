"""Public wrapper for the Gram kernel.

``gram(G)`` dispatches to the Pallas kernel (compiled on TPU, interpret mode
elsewhere) or the XLA reference — callers pick via ``impl=``; the distributed
aggregator defaults to ``xla`` so the multi-pod dry-run lowers on the host
platform, and flips to ``pallas`` on real TPU via config.
"""

from __future__ import annotations

import jax

from repro.kernels.gram.kernel import gram_pallas
from repro.kernels.gram.ref import gram_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gram(G, *, impl: str = "xla", block_n: int = 1024):
    """K = G^T G (fp32). impl: 'xla' | 'pallas' | 'pallas_interpret'."""
    if impl == "xla":
        return gram_ref(G)
    if impl == "pallas":
        return gram_pallas(G, block_n=block_n, interpret=not on_tpu())
    if impl == "pallas_interpret":
        return gram_pallas(G, block_n=block_n, interpret=True)
    raise ValueError(f"unknown impl {impl!r}")
