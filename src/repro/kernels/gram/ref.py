"""Pure-jnp oracles for the Gram kernels.

``chunk_schedule`` is the shared (pure-Python) chunk-sampling plan used by
both the fused Pallas kernel and the XLA reference, so the two paths see
byte-identical coordinate subsets.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(G: jnp.ndarray) -> jnp.ndarray:
    """K = G^T G accumulated in fp32.  G: (n, p) -> K: (p, p) fp32."""
    Gf = G.astype(jnp.float32)
    return Gf.T @ Gf


def chunk_schedule(n: int, block_n: int, stride: int):
    """Static chunk-sampling plan for the one-pass tree Gram.

    The fused kernel streams the concatenated (W, n) gradient row-stack as
    ``block_n``-wide chunks.  ``stride`` > 1 keeps every stride-th *chunk*
    (the stride is folded into the Pallas index map — no strided copy is
    ever materialized), and the result is rescaled by the exact inverse
    sampling fraction so the Gram diagonal stays unbiased.  Inputs smaller
    than one chunk are returned exact (scale 1).

    Returns:
      (kept, n_pad, scale): number of grid steps, padded coordinate count
      (zero padding, contributes nothing), and the fp32 rescale factor
      ``n / coords_covered``.
    """
    if n <= 0:
        raise ValueError(f"chunk_schedule: need n > 0, got {n}")
    stride = max(1, stride)
    total = -(-n // block_n)                     # ceil: chunks covering n
    kept = total if stride == 1 else max(1, -(-total // stride))
    covered = 0
    for j in range(kept):
        off = j * stride * block_n
        covered += max(0, min(block_n, n - off))
    n_pad = max(-(-n // block_n) * block_n,
                (kept - 1) * stride * block_n + block_n)
    return kept, n_pad, float(n) / float(covered)


def piece_plan(sizes, block_n: int, stride: int):
    """Static (leaf, start, length) pieces covering the kept chunks.

    Maps the kept chunks of the conceptual packed (W, n) stream back onto
    per-leaf coordinate ranges, merging contiguous ranges of the same leaf
    (at stride 1 every chunk is kept, so the plan collapses to one piece
    per leaf).  This lets the XLA backend consume the *identical* sampled
    coordinate set as the packed Pallas kernel without ever materializing
    the packed copy — on CPU the pack is pure memory-bandwidth tax.

    Returns:
      (pieces, scale): pieces is a list of (leaf_index, start, length)
      over flattened per-leaf coordinates; scale as in
      :func:`chunk_schedule`.
    """
    n = sum(sizes)
    kept, _, scale = chunk_schedule(n, block_n, stride)
    stride = max(1, stride)
    starts = [0]
    for s in sizes:
        starts.append(starts[-1] + s)
    pieces: list[tuple[int, int, int]] = []
    for j in range(kept):
        off = j * stride * block_n
        end = min(off + block_n, n)
        for li in range(len(sizes)):
            a, b = max(off, starts[li]), min(end, starts[li + 1])
            if a >= b:
                continue
            if (pieces and pieces[-1][0] == li
                    and starts[li] + pieces[-1][1] + pieces[-1][2] == a):
                pieces[-1] = (li, pieces[-1][1], pieces[-1][2] + b - a)
            else:
                pieces.append((li, a - starts[li], b - a))
    return pieces, scale


def tree_gram_pieces_ref(leaves, *, sketch_stride: int = 1,
                         block_n: int = 1024) -> jnp.ndarray:
    """XLA fused tree Gram: Gram additivity over the static piece plan.

    Numerically the same coordinate subset as the packed kernel (identical
    ``chunk_schedule``), accumulated piece by piece in fp32 — no packed
    (W, n) copy.  Leaves may be bf16; ``preferred_element_type`` keeps
    accumulation fp32.
    """
    ms = [leaf.reshape(leaf.shape[0], -1) for leaf in leaves]
    pieces, scale = piece_plan([m.shape[1] for m in ms], block_n,
                               sketch_stride)
    w = ms[0].shape[0]
    K = jnp.zeros((w, w), jnp.float32)
    for li, start, length in pieces:
        # (n_piece, W) with the contraction over dim 0 — the layout the
        # CPU/TPU dot handles best for tall-skinny Grams.
        piece = jax.lax.dynamic_slice_in_dim(ms[li], start, length,
                                             axis=1).T
        K = K + jax.lax.dot_general(
            piece, piece, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return K * scale if scale != 1.0 else K


def tree_gram_chunk_ref(X: jnp.ndarray, *, sketch_stride: int = 1,
                        block_n: int = 1024) -> jnp.ndarray:
    """XLA reference for the fused tree Gram:  K = scale * X_S X_S^T.

    X is the worker-major (W, n) row-stack of every flattened leaf; X_S is
    the chunk subset from :func:`chunk_schedule`.  Inputs stay in their
    own dtype (bf16 allowed); accumulation is fp32 via
    ``preferred_element_type``.
    """
    w, n = X.shape
    kept, n_pad, scale = chunk_schedule(n, block_n, sketch_stride)
    if sketch_stride <= 1:
        Xs = X
    else:
        Xp = jnp.zeros((w, n_pad), X.dtype).at[:, :n].set(X)
        Xs = jnp.concatenate(
            [jax.lax.dynamic_slice_in_dim(Xp, j * sketch_stride * block_n,
                                          block_n, axis=1)
             for j in range(kept)], axis=1)
    K = jax.lax.dot_general(Xs, Xs, dimension_numbers=(((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return K * scale if scale != 1.0 else K
