"""Pure-jnp oracle for the Gram kernel."""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(G: jnp.ndarray) -> jnp.ndarray:
    """K = G^T G accumulated in fp32.  G: (n, p) -> K: (p, p) fp32."""
    Gf = G.astype(jnp.float32)
    return Gf.T @ Gf
