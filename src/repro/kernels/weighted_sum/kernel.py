"""Fused weighted-combine kernel:  d = G @ c  (the FA update, Alg. 1 line 6).

This is a memory-bound streaming op (read n*p, write n): each grid step
pulls a (block_n, p_pad) tile of G into VMEM, multiplies by the replicated
weight row c (VMEM-resident, index_map constant), and writes the (block_n, 1)
output tile.  Fusing the scale-and-reduce avoids materializing the scaled
G (the naive XLA schedule for `(G * c).sum(1)` at n ~ 1e9 would) and keeps
arithmetic intensity at the streaming roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wsum_kernel(g_ref, c_ref, d_ref):
    g = g_ref[...].astype(jnp.float32)        # (block_n, p_pad)
    c = c_ref[...].astype(jnp.float32)        # (1, p_pad)
    d_ref[...] = jnp.sum(g * c, axis=1, keepdims=True).astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def weighted_sum_pallas(G: jnp.ndarray, c: jnp.ndarray, *,
                        block_n: int = 2048, interpret: bool = True):
    """d = G @ c.  G: (n, p), c: (p,) -> (n,) in G.dtype."""
    n, p = G.shape
    p_pad = max(128, -(-p // 128) * 128)
    n_pad = -(-n // block_n) * block_n
    Gp = jnp.zeros((n_pad, p_pad), G.dtype).at[:n, :p].set(G)
    cp = jnp.zeros((1, p_pad), c.dtype).at[0, :p].set(c)

    d = pl.pallas_call(
        _wsum_kernel,
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((block_n, p_pad), lambda i: (i, 0)),
                  pl.BlockSpec((1, p_pad), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), G.dtype),
        interpret=interpret,
    )(Gp, cp)
    return d[:n, 0]
