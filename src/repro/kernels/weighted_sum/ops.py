"""Public wrapper for the weighted-combine kernel (see gram/ops.py)."""

from __future__ import annotations

from repro.kernels.gram.ops import on_tpu
from repro.kernels.weighted_sum.kernel import weighted_sum_pallas
from repro.kernels.weighted_sum.ref import weighted_sum_ref


def weighted_sum(G, c, *, impl: str = "xla", block_n: int = 2048):
    """d = G @ c. impl: 'xla' | 'pallas' | 'pallas_interpret'."""
    if impl == "xla":
        return weighted_sum_ref(G, c)
    if impl == "pallas":
        if on_tpu():
            return weighted_sum_pallas(G, c, block_n=block_n,
                                       interpret=False)
        return weighted_sum_ref(G, c)   # production fallback off-TPU
    if impl == "pallas_interpret":
        return weighted_sum_pallas(G, c, block_n=block_n, interpret=True)
    raise ValueError(f"unknown impl {impl!r}")
