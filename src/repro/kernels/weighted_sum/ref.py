"""Pure-jnp oracle for the weighted-combine kernel."""

from __future__ import annotations

import jax.numpy as jnp


def weighted_sum_ref(G: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """d = G @ c with fp32 accumulation.  G: (n, p), c: (p,) -> d: (n,) in
    G.dtype (the gradient dtype the optimizer consumes)."""
    d = G.astype(jnp.float32) @ c.astype(jnp.float32)
    return d.astype(G.dtype)
