import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

For each combination this harness:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. derives per-arch sharding rules (kv-head vs head-dim cache sharding,
     expert- vs expert-mlp parallelism, ...),
  3. AOT-lowers ``init_params`` to obtain the GSPMD-propagated parameter
     shardings *without allocating* (command-r fp32 params would be 120GB),
  4. lowers + compiles the real train_step / prefill_step / serve_step with
     those shardings against ShapeDtypeStruct inputs,
  5. records memory_analysis, cost_analysis, and the per-collective byte
     volumes parsed from the partitioned HLO,
  6. writes one JSON per combination under --out (benchmarks/roofline.py
     consumes these).

The device-count override above MUST precede any other import that could
initialize jax.  Train shapes lower with the Flag Aggregator ON (that is
the paper's technique in the step); decode shapes lower ``serve_step``
(one token against a full-length or ring KV cache); ``long_500k`` uses the
documented SWA-4096 variant for full-attention archs (DESIGN.md §6).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun [--scan-layers] [--agg flag]
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, input_specs
from repro.core.flag import FlagConfig
from repro.dist import serve_step as serve_lib
from repro.dist.aggregation import AggregatorConfig
from repro.dist.sharding import use_sharding
from repro.dist.train_step import TrainConfig, build_train_step
from repro.launch.mesh import make_production_mesh, worker_count
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import constant, sgd

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def rules_for(cfg: ModelConfig, mesh, *, serving: bool) -> dict:
    """Per-arch logical->mesh overrides (see dist.sharding.DEFAULT_RULES)."""
    model = mesh.shape["model"]
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    rules: dict = {"worker": dp, "batch": dp}
    if serving:
        rules["sub_batch"] = dp          # serve batch = global batch
    rules["heads"] = "model" if cfg.num_heads % model == 0 else None
    if cfg.num_kv_heads % model == 0:
        rules["kv_heads"], rules["head_dim"] = "model", None
    elif cfg.head_dim % model == 0:
        # contraction-sharded KV cache (GQA kv < model axis): shard head_dim
        rules["kv_heads"], rules["head_dim"] = None, "model"
    else:
        rules["kv_heads"], rules["head_dim"] = None, None
    if cfg.moe is not None:
        if cfg.moe.num_experts % model == 0:
            rules["experts"], rules["expert_mlp"] = "model", None   # EP
        else:
            rules["experts"], rules["expert_mlp"] = None, "model"   # TP
    return rules


def variant_for(cfg: ModelConfig, shape_name: str):
    """long_500k on full-attention archs -> sliding-window-4096 variant."""
    if shape_name == "long_500k" and cfg.window is None \
            and cfg.arch_type not in ("ssm", "hybrid"):
        return cfg.replace(window=4096), "swa4096"
    return cfg, ""


def _replicated(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, P(*([None] * len(s.shape)))), spec_tree)


def _batch_shardings(mesh, spec_tree, lead_axes):
    def one(s):
        if s.shape and s.shape[0] % _axes_size(mesh, lead_axes) == 0:
            return NamedSharding(mesh, P(lead_axes,
                                         *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(s.shape))))
    return jax.tree.map(one, spec_tree)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              scan_layers: bool = True, agg: str = "flag",
              sketch_stride: int = 1, zero1: bool = False,
              gram_dtype: str = "float32", microbatch: int = 0,
              extra_rules: dict | None = None):
    """Lower + compile one combination; returns a result dict."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    cfg, variant = variant_for(cfg, shape_name)
    cfg = cfg.replace(scan_layers=scan_layers)
    W = worker_count(mesh)
    dp = ("pod", "data") if multi_pod else ("data",)
    serving = shape.kind != "train"
    rules = rules_for(cfg, mesh, serving=serving)
    if extra_rules:
        rules.update(extra_rules)
    if microbatch == 0:  # auto: keep per-microbatch tokens ~<= 16k at 4k seq
        per_worker = shape.global_batch // max(W, 1)
        microbatch = max(1, per_worker // 4) if cfg.d_model >= 4096 else 1
        while per_worker % microbatch:
            microbatch -= 1
    total_devices = mesh.size

    key = jax.random.PRNGKey(0)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "variant": variant, "kind": shape.kind, "workers": W,
        "scan_layers": scan_layers, "aggregator": agg if not serving else "",
        "sketch_stride": sketch_stride, "zero1": zero1,
    }

    with mesh, use_sharding(mesh, rules):
        # --- parameter shardings via AOT (no allocation) ---
        init_fn = lambda k: transformer.init_params(k, cfg)
        init_compiled = jax.jit(init_fn).lower(key).compile()
        p_shardings = init_compiled.output_shardings
        p_specs = jax.eval_shape(init_fn, key)

        if shape.kind == "train":
            opt = sgd(momentum=0.9)
            o_specs = jax.eval_shape(lambda p: opt.init(p), p_specs)
            o_shardings = jax.tree.map(lambda s: s, p_shardings)
            o_shardings = {"mu": o_shardings}
            if zero1:
                # ZeRO-1: additionally shard the optimizer state's first
                # divisible unsharded dim over the data axis.
                def zshard(sh, spec):
                    pspec = list(sh.spec) + [None] * (len(spec.shape)
                                                      - len(sh.spec))
                    for i, (dim, cur) in enumerate(zip(spec.shape, pspec)):
                        if cur is None and dim % _axes_size(mesh, ("data",)) == 0:
                            pspec[i] = "data"
                            break
                    return NamedSharding(mesh, P(*pspec))
                o_shardings = {"mu": jax.tree.map(zshard, p_shardings,
                                                  p_specs)}
            tc = TrainConfig(
                aggregator=AggregatorConfig(
                    name=agg, f=2, flag=FlagConfig(lam=float(W)),
                    sketch_stride=sketch_stride, gram_dtype=gram_dtype),
                attack="none", microbatch_splits=microbatch)
            result["microbatch_splits"] = microbatch

            def wsharding(sh, spec):
                pspec = list(sh.spec) + [None] * (len(spec.shape)
                                                  - len(sh.spec))
                return NamedSharding(mesh, P(dp, *pspec))
            g_shardings = jax.tree.map(wsharding, p_shardings, p_specs)
            step_fn = build_train_step(cfg, tc, opt, constant(1e-3),
                                       grad_shardings=g_shardings,
                                       param_shardings=p_shardings)
            batch_specs = input_specs(cfg, shape, workers=W)
            b_shardings = _batch_shardings(mesh, batch_specs, dp)
            rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shardings, o_shardings, b_shardings,
                              None, None),
                out_shardings=(p_shardings, o_shardings, None),
            ).lower(p_specs, o_specs, batch_specs, rng_spec, step_spec)

        elif shape.kind == "prefill":
            step_fn = serve_lib.build_prefill_step(cfg)
            batch_specs = input_specs(cfg, shape)
            b_shardings = _batch_shardings(mesh, batch_specs, dp)
            lowered = jax.jit(
                step_fn, in_shardings=(p_shardings, b_shardings),
            ).lower(p_specs, batch_specs)

        else:  # decode
            cache_fn = lambda: transformer.init_caches(
                cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
            cache_compiled = jax.jit(cache_fn).lower().compile()
            c_shardings = cache_compiled.output_shardings
            c_specs = jax.eval_shape(cache_fn)
            step_fn = serve_lib.build_serve_step(cfg, max_len=shape.seq_len)
            specs = input_specs(cfg, shape)
            tok_spec = specs["tokens"]
            tok_sh = _batch_shardings(mesh, tok_spec, dp)
            lowered = jax.jit(
                step_fn,
                in_shardings=(p_shardings, c_shardings, tok_sh, None),
                out_shardings=(None, c_shardings),
            ).lower(p_specs, c_specs, tok_spec, specs["step"])

        compiled = lowered.compile()

    # --- analyses ---
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per computation
        cost = cost[0] if cost else {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    from repro.analysis.hlo import parse_collectives, parse_cost
    coll = parse_collectives(hlo, total_devices)
    hcost = parse_cost(hlo)

    result.update({
        "ok": True,
        "elapsed_s": round(time.time() - t0, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        # loop-corrected (while trip counts folded in; see repro.analysis.hlo):
        "flops_corrected_per_device": hcost.flops,
        "hbm_bytes_corrected_per_device": hcost.hbm_bytes,
        "flops_dots_raw_per_device": hcost.raw_flops,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0)
                           + getattr(mem, "argument_size_in_bytes", 0)),
        },
        "collectives": {
            "total_moved_bytes_per_device": coll.total_moved_bytes,
            "per_kind_bytes": coll.per_kind_bytes,
            "per_kind_count": coll.per_kind_count,
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    })
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (comma-separated ok)")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all' (comma-separated ok)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer stack (bigger HLO, slower "
                         "compile; collective counts are loop-corrected "
                         "either way via repro.analysis.hlo)")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="grad-accumulation splits per worker (0 = auto)")
    ap.add_argument("--agg", default="flag")
    ap.add_argument("--sketch-stride", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--gram-dtype", default="float32")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                mesh_tag = "multi" if multi_pod else "single"
                name = f"{arch}_{shape_name}_{mesh_tag}"
                if args.tag:
                    name += f"_{args.tag}"
                out_path = os.path.join(args.out, name + ".json")
                if os.path.exists(out_path):
                    print(f"[skip] {name} (exists)")
                    continue
                print(f"[lower] {name} ...", flush=True)
                try:
                    res = lower_one(arch, shape_name, multi_pod=multi_pod,
                                    scan_layers=not args.unroll,
                                    agg=args.agg,
                                    sketch_stride=args.sketch_stride,
                                    zero1=args.zero1,
                                    gram_dtype=args.gram_dtype,
                                    microbatch=args.microbatch)
                    print(f"[ok]    {name}: "
                          f"flops/dev={res['flops_per_device']:.3e} "
                          f"coll/dev={res['collectives']['total_moved_bytes_per_device']/1e6:.1f}MB "
                          f"peak={res['memory']['peak_bytes']/1e9:.2f}GB "
                          f"({res['elapsed_s']}s)", flush=True)
                except Exception as e:
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                    failures.append(name)
                    print(f"[FAIL]  {name}: {type(e).__name__}: "
                          f"{str(e)[:300]}", flush=True)
                with open(out_path, "w") as f:
                    json.dump(res, f, indent=1, default=float)

    print(f"\ndone. {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
