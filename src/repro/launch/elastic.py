"""Elastic fault-injection training driver: scheduled kills, crash-safe
resume, verified bit-exact trajectories.

The Byzantine harness answers "what if workers lie?"; this driver answers
"what if the *system* fails?" — it runs multi-round training where the
training process is killed at scheduled steps (taking all in-memory state
with it, and leaving a deliberately *torn* checkpoint behind to exercise
the crash-safe store), then restarts from the newest complete checkpoint,
replays, and continues.  Worker churn (``--faults``, see
:mod:`repro.dist.membership`) composes freely with the kills: membership
is a pure function of the step index, so a resumed run sees the same
worker subsets it would have seen uninterrupted.

The contract the driver verifies (``--verify``) is the resume invariant:

    loss trajectory of  (run -> kill -> resume)*  ==  uninterrupted run

bit-exact (tolerance ``--tol``, default 1e-6, incl. error-feedback
codecs — the EF memory is part of the checkpointed state).  This holds
because every step is a pure function of ``(state, step_index)``: batches
derive from the step index, per-step rng is ``PRNGKey(t)``, membership is
scheduled, the LR schedule is built on the persisted total horizon, and
the checkpoint round-trips fp32/bf16 state bitwise.

    PYTHONPATH=src python -m repro.launch.elastic --verify \
        --steps 12 --kill-at 5,9 --ckpt-every 3 --workers 6 \
        --aggregator flag --attack sign_flip --byzantine 1 --codec signsgd
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import time
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.checkpoint import (checkpoint_meta, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpoint import _commit_name, _state_name, _step_dir
from repro.comm import CODECS, CommConfig, init_ef
from repro.configs import get_config, reduce_for_smoke
from repro.core.flag import FlagConfig
from repro.data.pipeline import WorkerDataConfig, lm_worker_batches
from repro.data.synthetic import SyntheticLM
from repro.dist.aggregation import AggregatorConfig
from repro.dist.membership import FAULTS, get_fault_schedule
from repro.dist.train_step import TrainConfig, build_train_step, init_train_state
from repro.optim import adamw, warmup_cosine

__all__ = ["ElasticConfig", "build_harness", "run_reference", "run_elastic",
           "verify_elastic"]


@dataclass
class ElasticConfig:
    """One elastic training scenario (reduced arch, CPU-sized defaults)."""

    arch: str = "smollm-360m"
    steps: int = 12                  # TOTAL horizon
    workers: int = 6
    per_worker_batch: int = 2
    seq: int = 32
    aggregator: str = "flag"
    attack: str = "none"
    byzantine: int = 0
    codec: str = "none"
    error_feedback: bool | None = None
    faults: str = "none"
    faults_kw: dict = field(default_factory=dict)
    lam: float = 0.0                 # small-p default (EXPERIMENTS.md)
    lr: float = 3e-3
    ckpt_every: int = 3
    seed: int = 0


class Harness(NamedTuple):
    """Built scenario: jitted step + everything needed to drive it."""

    cfg: ElasticConfig
    model_cfg: object
    tc: TrainConfig
    comm: CommConfig
    opt: object
    step_fn: object
    task: object
    wdc: WorkerDataConfig


def build_harness(cfg: ElasticConfig) -> Harness:
    """Build (and jit) the scenario's train step once; rounds reuse it."""
    model_cfg = reduce_for_smoke(get_config(cfg.arch)).replace(
        frontend=None, num_prefix_embeds=0)
    comm = CommConfig(codec=cfg.codec, error_feedback=cfg.error_feedback)
    tc = TrainConfig(
        aggregator=AggregatorConfig(
            name=cfg.aggregator, f=cfg.byzantine,
            flag=FlagConfig(
                lam=cfg.lam,
                regularizer="pairwise" if cfg.lam else "none")),
        attack=cfg.attack, attack_f=cfg.byzantine, comm=comm,
        faults=get_fault_schedule(cfg.faults, cfg.workers, **cfg.faults_kw))
    opt = adamw(weight_decay=0.0)
    sched = warmup_cosine(cfg.lr, cfg.steps, warmup=min(5, cfg.steps // 2))
    step_fn = jax.jit(build_train_step(model_cfg, tc, opt, sched))
    task = SyntheticLM(vocab_size=model_cfg.vocab_size)
    wdc = WorkerDataConfig(workers=cfg.workers,
                           per_worker_batch=cfg.per_worker_batch)
    return Harness(cfg, model_cfg, tc, comm, opt, step_fn, task, wdc)


def _init_state(h: Harness):
    params, opt_state = init_train_state(
        jax.random.PRNGKey(h.cfg.seed), h.model_cfg, h.opt)
    if h.comm.wants_ef:
        return params, opt_state, init_ef(params, h.cfg.workers)
    return params, opt_state


def _one_step(h: Harness, state, t: int):
    """Advance ``state`` by the (pure) step ``t``; returns (state, metrics)."""
    batch = lm_worker_batches(h.task, h.wdc, t, h.cfg.seq)
    rng = jax.random.PRNGKey(t)
    ti = jnp.asarray(t, jnp.int32)
    if h.comm.wants_ef:
        params, opt_state, ef = state
        params, opt_state, m, ef = h.step_fn(params, opt_state, batch, rng,
                                             ti, ef)
        return (params, opt_state, ef), m
    params, opt_state = state
    params, opt_state, m = h.step_fn(params, opt_state, batch, rng, ti)
    return (params, opt_state), m


def run_reference(h: Harness) -> dict[int, float]:
    """The uninterrupted run: per-step losses for the full horizon."""
    state = _init_state(h)
    losses = {}
    for t in range(h.cfg.steps):
        state, m = _one_step(h, state, t)
        losses[t] = float(m["loss"])
    return losses


def _write_torn_checkpoint(ckpt_dir: str, step: int, tree) -> None:
    """Simulate a SIGKILL mid-save: a step dir with a half-written npz and
    no commit marker.  ``latest_step`` must skip it (asserted by resume)."""
    save_checkpoint(ckpt_dir, step, tree)
    step_dir = _step_dir(ckpt_dir, step)
    os.unlink(os.path.join(step_dir, _commit_name(0)))
    state_path = os.path.join(step_dir, _state_name(0))
    size = os.path.getsize(state_path)
    with open(state_path, "rb+") as f:
        f.truncate(max(size // 2, 1))


def run_elastic(h: Harness, ckpt_dir: str,
                kill_at: tuple[int, ...] = ()) -> dict:
    """Multi-round kill-and-resume training.

    Each kill at step k discards all in-memory state after executing step
    k-1 (and leaves a torn checkpoint at k, exercising the crash-safe
    store); the next round restores the newest *complete* checkpoint and
    replays from there.  Every re-executed step must reproduce the loss of
    its first execution exactly — the per-step replay mismatches are
    returned for the caller to assert on.

    Returns a dict: ``losses`` {step: loss} (first execution wins),
    ``replayed`` (re-executed step count), ``replay_mismatch`` (max abs
    loss diff across replays), ``rounds``, ``kills`` (the kill steps that
    actually fired).
    """
    cfg = h.cfg
    if os.path.isdir(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    kills = sorted(k for k in set(kill_at) if 0 < k < cfg.steps)
    extra = {"total_steps": cfg.steps}
    losses: dict[int, float] = {}
    replayed = 0
    replay_mismatch = 0.0
    rounds = 0
    fired = []

    while True:
        rounds += 1
        # --- (re)start: restore the newest complete checkpoint, or init.
        last = latest_step(ckpt_dir)
        if last is None:
            state, step0 = _init_state(h), 0
        else:
            saved_total = checkpoint_meta(ckpt_dir)["extra"]["total_steps"]
            assert saved_total == cfg.steps, (saved_total, cfg.steps)
            state, step0 = load_checkpoint(ckpt_dir, _init_state(h))
        kill = next((k for k in kills if k > step0), None)
        stop = cfg.steps if kill is None else kill
        for t in range(step0, stop):
            state, m = _one_step(h, state, t)
            loss = float(m["loss"])
            if t in losses:
                replayed += 1
                replay_mismatch = max(replay_mismatch,
                                      abs(loss - losses[t]))
            else:
                losses[t] = loss
            if (t + 1) % cfg.ckpt_every == 0 and (t + 1) < stop:
                save_checkpoint(ckpt_dir, t + 1, state, extra=extra)
        if kill is None:
            save_checkpoint(ckpt_dir, cfg.steps, state, extra=extra)
            return {"losses": losses, "replayed": replayed,
                    "replay_mismatch": replay_mismatch, "rounds": rounds,
                    "kills": fired}
        # --- the kill: in-memory state dies here; the torn dir left behind
        # is what a real SIGKILL mid-save produces.
        fired.append(kill)
        kills = [k for k in kills if k != kill]
        _write_torn_checkpoint(ckpt_dir, stop, state)
        del state


def verify_elastic(h: Harness, ckpt_dir: str, kill_at: tuple[int, ...],
                   tol: float = 1e-6) -> dict:
    """Run reference + elastic and compare trajectories.

    Returns the elastic result dict extended with ``max_diff`` and ``ok``.
    """
    ref = run_reference(h)
    out = run_elastic(h, ckpt_dir, kill_at)
    diffs = [abs(out["losses"][t] - ref[t]) for t in range(h.cfg.steps)]
    out["max_diff"] = max(diffs)
    out["ok"] = (out["max_diff"] <= tol
                 and out["replay_mismatch"] <= tol
                 and len(out["losses"]) == h.cfg.steps)
    return out


def _parse_fault_args(pairs):
    kw = {}
    for p in pairs or ():
        k, _, v = p.partition("=")
        kw[k] = int(v)
    return kw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--aggregator", default="flag")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--byzantine", type=int, default=0)
    ap.add_argument("--codec", default="none", choices=("none",) + CODECS)
    ap.add_argument("--no-ef", action="store_true")
    ap.add_argument("--faults", default="none", choices=sorted(FAULTS))
    ap.add_argument("--fault-arg", action="append", metavar="K=V",
                    help="fault scenario int kwarg, repeatable "
                         "(e.g. --fault-arg at=4 --fault-arg n=2)")
    ap.add_argument("--lam", type=float, default=0.0)
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_elastic_ckpt")
    ap.add_argument("--kill-at", default="5,9",
                    help="comma-separated steps at which the process dies")
    ap.add_argument("--verify", action="store_true",
                    help="compare against the uninterrupted run; exit "
                         "nonzero on trajectory mismatch")
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--json-out", default="")
    args = ap.parse_args(argv)

    cfg = ElasticConfig(
        arch=args.arch, steps=args.steps, workers=args.workers,
        per_worker_batch=args.per_worker_batch, seq=args.seq,
        aggregator=args.aggregator, attack=args.attack,
        byzantine=args.byzantine, codec=args.codec,
        error_feedback=False if args.no_ef else None,
        faults=args.faults, faults_kw=_parse_fault_args(args.fault_arg),
        lam=args.lam, ckpt_every=args.ckpt_every)
    kill_at = tuple(int(k) for k in args.kill_at.split(",") if k)

    print(f"elastic: arch={cfg.arch} W={cfg.workers} agg={cfg.aggregator} "
          f"attack={cfg.attack}(f={cfg.byzantine}) codec={cfg.codec} "
          f"faults={cfg.faults} steps={cfg.steps} kill_at={kill_at}")
    t0 = time.time()
    h = build_harness(cfg)
    if args.verify:
        out = verify_elastic(h, args.ckpt_dir, kill_at, tol=args.tol)
        print(f"rounds={out['rounds']} kills={out['kills']} "
              f"replayed={out['replayed']} steps "
              f"(replay mismatch {out['replay_mismatch']:.2e}) "
              f"max |loss diff| vs uninterrupted = {out['max_diff']:.2e} "
              f"({time.time() - t0:.0f}s)")
        print("VERIFY:", "OK" if out["ok"] else "FAILED")
    else:
        out = run_elastic(h, args.ckpt_dir, kill_at)
        print(f"rounds={out['rounds']} kills={out['kills']} "
              f"replayed={out['replayed']} final loss "
              f"{out['losses'][cfg.steps - 1]:.4f} "
              f"({time.time() - t0:.0f}s)")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({k: v for k, v in out.items() if k != "losses"}
                      | {"losses": {str(t): l
                                    for t, l in sorted(out["losses"].items())}},
                      f, indent=1)
    if args.verify and not out["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
