"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run process
must set XLA_FLAGS *before* the first jax initialization.

Mesh shapes (TPU v5e):
  single pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips

The FA *worker* axis is (pod, data): p = 16 workers single-pod, 32 workers
multi-pod; the ``model`` axis carries Megatron-style tensor parallelism.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny (data, model) mesh over whatever devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    model = next((m for m in (4, 2) if n % m == 0 and n > m), 1)
    return jax.make_mesh((n // model, model), ("data", "model"))


def worker_count(mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            n *= mesh.shape[ax]
    return n
