"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run process
must set XLA_FLAGS *before* the first jax initialization.

Mesh shapes (TPU v5e):
  single pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips

The FA *worker* axis is (pod, data): p = 16 workers single-pod, 32 workers
multi-pod; the ``model`` axis carries Megatron-style tensor parallelism.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def _model_factor(n: int) -> int:
    """Widest model axis (of 4/2/1) that divides ``n`` with data > 1."""
    return next((m for m in (4, 2) if n % m == 0 and n > m), 1)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny (data, model) mesh over whatever devices exist (CPU tests)."""
    return make_host_mesh(n_devices)


def make_host_mesh(n_devices: int | None = None):
    """(data, model) mesh over the FIRST ``n_devices`` host devices.

    The sharded-aggregation tests and ``benchmarks/sharded_agg.py`` sweep
    device counts on a single host
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``), which needs
    meshes over a *prefix* of the device list — ``jax.make_mesh`` insists
    on consuming every device, so this builds the Mesh explicitly.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = n_devices or len(devs)
    if n > len(devs):
        raise ValueError(f"make_host_mesh: asked for {n} devices but only "
                         f"{len(devs)} exist (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={n})")
    model = _model_factor(n)
    return Mesh(np.asarray(devs[:n]).reshape(n // model, model),
                ("data", "model"))


def worker_count(mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            n *= mesh.shape[ax]
    return n
