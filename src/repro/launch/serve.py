"""Serving launcher: batched greedy decoding with the production cache
layout (ring buffer for SWA archs, full-length otherwise).

    PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-9b \
        --debug --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.dist.serve_step import build_serve_step
from repro.models import transformer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--debug", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.debug:
        cfg = reduce_for_smoke(cfg).replace(frontend=None,
                                            num_prefix_embeds=0)
    max_len = args.prompt_len + args.gen + 1
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    caches = transformer.init_caches(cfg, args.batch, max_len, jnp.float32)
    step_fn = jax.jit(build_serve_step(cfg, max_len=max_len))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(args.prompt_len):
        tok, caches = step_fn(params, caches, prompts[:, t:t + 1],
                              jnp.asarray(t, jnp.int32))
    prefill_s = time.time() - t0
    out = []
    t0 = time.time()
    for t in range(args.prompt_len, args.prompt_len + args.gen):
        out.append(tok)
        tok, caches = step_fn(params, caches, tok, jnp.asarray(t, jnp.int32))
    decode_s = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len} steps in {prefill_s:.2f}s, "
          f"decode {args.gen} steps in {decode_s:.2f}s "
          f"({args.gen * args.batch / max(decode_s, 1e-9):.1f} tok/s)")
    for row in jax.device_get(gen)[:2]:
        print("  ", row.tolist()[:16], "...")


if __name__ == "__main__":
    main()
