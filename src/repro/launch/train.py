"""Training launcher.

On a real pod this runs under the production mesh with the shardings the
dry-run validates; on CPU (`--debug`) it trains the reduced variant of the
selected architecture end-to-end on the synthetic LM task — the same code
path, one device.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --debug --steps 100 --aggregator flag --attack random --byzantine 2

``--steps`` is the *total* training horizon: a resumed run (``--ckpt-dir``
pointing at existing checkpoints) completes the remaining steps on the
original LR schedule — the horizon is persisted in the checkpoint meta, so
the warmup/decay shape cannot silently re-warm on the leftover step count.
With a compression codec that carries error feedback (``--codec signsgd``
/ ``topk``) the EF memory is part of the checkpointed state, so a resumed
compressed run keeps its error memory instead of restarting from zero.
Worker churn is injected with ``--faults`` (see repro.dist.membership);
the fault-injection *process-kill* scenarios live in
``repro.launch.elastic``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import (checkpoint_meta, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.comm import CODECS, CommConfig, init_ef
from repro.configs import get_config, reduce_for_smoke
from repro.core.flag import FlagConfig
from repro.data.pipeline import WorkerDataConfig, lm_worker_batches
from repro.data.synthetic import SyntheticLM
from repro.dist.aggregation import AggregatorConfig
from repro.dist.membership import FAULTS, get_fault_schedule
from repro.dist.sharding import use_sharding
from repro.dist.train_step import TrainConfig, build_train_step, init_train_state
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               worker_count)
from repro.optim import adamw, sgd, warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on local devices (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100,
                    help="TOTAL training horizon (resume completes it)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--per-worker-batch", type=int, default=4)
    ap.add_argument("--aggregator", default="flag")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--byzantine", type=int, default=0)
    ap.add_argument("--codec", default="none", choices=("none",) + CODECS)
    ap.add_argument("--no-ef", action="store_true",
                    help="disable error feedback for biased codecs")
    ap.add_argument("--faults", default="none", choices=sorted(FAULTS),
                    help="worker-churn scenario (repro.dist.membership)")
    ap.add_argument("--sharded-agg", action="store_true",
                    help="mesh-sharded aggregation (repro.dist.sharded): "
                         "coordinate shards per device, partial-Gram psum, "
                         "no full (W, n) stack on any device; in --debug "
                         "this activates a mesh over the local devices")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--lam", type=float, default=-1.0,
                    help="FA lambda (-1 = auto: p if p>6 else 0)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.debug:
        cfg = reduce_for_smoke(get_config(args.arch)).replace(
            frontend=None, num_prefix_embeds=0)
        # sharded aggregation needs a mesh even in debug: span the local
        # devices (1 on plain CPU; 8 under the forced-host-device flag).
        mesh = make_host_mesh() if args.sharded_agg else None
        W = args.workers
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        W = worker_count(mesh)

    lam = args.lam if args.lam >= 0 else (float(W) if W > 6 else 0.0)
    comm = CommConfig(codec=args.codec,
                      error_feedback=False if args.no_ef else None)
    tc = TrainConfig(
        aggregator=AggregatorConfig(
            name=args.aggregator, f=args.byzantine,
            flag=FlagConfig(lam=lam,
                            regularizer="pairwise" if lam else "none")),
        attack=args.attack, attack_f=args.byzantine, comm=comm,
        faults=get_fault_schedule(args.faults, W),
        sharded_agg=args.sharded_agg)
    opt = adamw() if args.optimizer == "adamw" else sgd(momentum=0.9)

    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    ef = init_ef(params, W) if comm.wants_ef else None

    total = args.steps
    step0 = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        # The LR horizon is a property of the *run*, not of this process
        # invocation: schedules must be rebuilt on the persisted total, or
        # a resumed run re-warms and re-decays on the leftover step count.
        saved_total = checkpoint_meta(args.ckpt_dir)["extra"].get(
            "total_steps")
        if saved_total is not None and saved_total != total:
            print("resume: using checkpointed horizon total_steps="
                  f"{saved_total} (ignoring --steps {total})")
            total = saved_total
        template = ((params, opt_state, ef) if comm.wants_ef
                    else (params, opt_state))
        flat = jax.tree_util.tree_flatten_with_path(template)[0]
        want = sorted(jax.tree_util.keystr(p) for p, _ in flat)
        saved = checkpoint_meta(args.ckpt_dir)["keys"]
        if saved != want:
            raise SystemExit(
                "resume state mismatch: the checkpoint holds "
                f"{len(saved)} leaves but this invocation expects "
                f"{len(want)} — most likely the --codec/--no-ef flags "
                "differ from the run that wrote the checkpoint (the EF "
                "memory is part of the checkpointed state); rerun with "
                "the original flags or start a fresh --ckpt-dir")
        state, step0 = load_checkpoint(args.ckpt_dir, template)
        if comm.wants_ef:
            params, opt_state, ef = state
        else:
            params, opt_state = state
        print(f"resumed from step {step0}")
    extra = {"total_steps": total}

    sched = warmup_cosine(args.lr, total, warmup=min(20, total // 5))
    step_fn = jax.jit(build_train_step(cfg, tc, opt, sched))
    task = SyntheticLM(vocab_size=cfg.vocab_size)
    wdc = WorkerDataConfig(workers=W, per_worker_batch=args.per_worker_batch)

    def ckpt_tree():
        return (params, opt_state, ef) if comm.wants_ef \
            else (params, opt_state)

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M workers={W} "
          f"agg={args.aggregator}(lam={lam}) attack={args.attack} "
          f"f={args.byzantine} codec={args.codec} faults={args.faults} "
          f"sharded_agg={args.sharded_agg} steps {step0}->{total}")
    t0 = time.time()
    ctx = use_sharding(mesh, {}) if mesh is not None else None
    if ctx:
        ctx.__enter__()
    try:
        for t in range(step0, total):
            batch = lm_worker_batches(task, wdc, t, args.seq)
            if comm.wants_ef:
                params, opt_state, m, ef = step_fn(
                    params, opt_state, batch, jax.random.PRNGKey(t),
                    jnp.asarray(t, jnp.int32), ef)
            else:
                params, opt_state, m = step_fn(params, opt_state, batch,
                                               jax.random.PRNGKey(t),
                                               jnp.asarray(t, jnp.int32))
            if t % args.log_every == 0 or t == total - 1:
                act = (f" act {int(m['active_workers'])}/{W}"
                       if "active_workers" in m else "")
                print(f"step {t:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e} "
                      f"|g| {float(m['grad_global_norm']):.3f}{act} "
                      f"({time.time() - t0:.0f}s)", flush=True)
            if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, t + 1, ckpt_tree(),
                                extra=extra)
    finally:
        if ctx:
            ctx.__exit__(None, None, None)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, total, ckpt_tree(), extra=extra)


if __name__ == "__main__":
    main()
