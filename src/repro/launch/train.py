"""Training launcher.

On a real pod this runs under the production mesh with the shardings the
dry-run validates; on CPU (`--debug`) it trains the reduced variant of the
selected architecture end-to-end on the synthetic LM task — the same code
path, one device.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --debug --steps 100 --aggregator flag --attack random --byzantine 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint, load_checkpoint, latest_step
from repro.configs import get_config, reduce_for_smoke
from repro.configs.shapes import SHAPES
from repro.core.flag import FlagConfig
from repro.data.pipeline import WorkerDataConfig, lm_worker_batches
from repro.data.synthetic import SyntheticLM
from repro.dist.aggregation import AggregatorConfig
from repro.dist.sharding import use_sharding
from repro.dist.train_step import TrainConfig, build_train_step, init_train_state
from repro.launch.mesh import make_production_mesh, worker_count
from repro.optim import adamw, sgd, warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--debug", action="store_true",
                    help="reduced config on local devices (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--per-worker-batch", type=int, default=4)
    ap.add_argument("--aggregator", default="flag")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--byzantine", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--lam", type=float, default=-1.0,
                    help="FA lambda (-1 = auto: p if p>6 else 0)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.debug:
        cfg = reduce_for_smoke(get_config(args.arch)).replace(
            frontend=None, num_prefix_embeds=0)
        mesh = None
        W = args.workers
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        W = worker_count(mesh)

    lam = args.lam if args.lam >= 0 else (float(W) if W > 6 else 0.0)
    tc = TrainConfig(
        aggregator=AggregatorConfig(
            name=args.aggregator, f=args.byzantine,
            flag=FlagConfig(lam=lam,
                            regularizer="pairwise" if lam else "none")),
        attack=args.attack, attack_f=args.byzantine)
    opt = adamw() if args.optimizer == "adamw" else sgd(momentum=0.9)
    sched = warmup_cosine(args.lr, args.steps, warmup=min(20, args.steps // 5))

    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step0 = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), step0 = load_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {step0}")

    step_fn = jax.jit(build_train_step(cfg, tc, opt, sched))
    task = SyntheticLM(vocab_size=cfg.vocab_size)
    wdc = WorkerDataConfig(workers=W, per_worker_batch=args.per_worker_batch)

    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M workers={W} "
          f"agg={args.aggregator}(lam={lam}) attack={args.attack} "
          f"f={args.byzantine}")
    t0 = time.time()
    ctx = use_sharding(mesh, {}) if mesh is not None else None
    if ctx:
        ctx.__enter__()
    try:
        for t in range(step0, step0 + args.steps):
            batch = lm_worker_batches(task, wdc, t, args.seq)
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jax.random.PRNGKey(t),
                                           jnp.asarray(t, jnp.int32))
            if t % args.log_every == 0 or t == step0 + args.steps - 1:
                print(f"step {t:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e} "
                      f"|g| {float(m['grad_global_norm']):.3f} "
                      f"({time.time() - t0:.0f}s)", flush=True)
            if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, t + 1, (params, opt_state))
    finally:
        if ctx:
            ctx.__exit__(None, None, None)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, step0 + args.steps,
                        (params, opt_state))


if __name__ == "__main__":
    main()
