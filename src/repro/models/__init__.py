"""Model substrate: composable transformer covering all assigned archetypes
(dense GQA, MoE, xLSTM, RG-LRU hybrid, audio/VLM decoder backbones)."""

from repro.models import transformer
from repro.models.config import ModelConfig, MoESettings

__all__ = ["ModelConfig", "MoESettings", "transformer"]
