"""GQA attention: chunked-flash training/prefill + cached decode.

Three execution paths:

* ``xla_flash`` — pure-XLA online-softmax attention, double ``lax.scan``
  over (q-chunks, k-chunks).  This is what the multi-pod dry-run lowers
  (Pallas doesn't compile on the host platform); the inner body is
  ``jax.checkpoint``-ed so the 4k training backward stores O(S) not O(S^2).
  Sliding-window attention takes a dynamic-slice fast path: each q-chunk
  only ever touches ``window + q_chunk`` keys, making SWA prefill O(S*w).
* ``repro.kernels.flash_attn`` — the Pallas TPU kernel, selected with
  ``impl='pallas'`` on real hardware (same math, tested equivalent).
* ``decode_attend`` — one-token GQA attention against a (possibly ring)
  KV cache: a masked einsum, O(cache) per step.

Layout convention: activations (batch, seq, d_model); caches
(batch, kv_heads, cache_len, head_dim); decode positions are a scalar step
count (lockstep batch decoding).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.kernels.flash_attn.ops import flash_attention
from repro.models import layers
from repro.models.config import ModelConfig

NEG = -1e30


# ---------------------------------------------------------------------------
# chunked flash attention in pure XLA
# ---------------------------------------------------------------------------

def _chunk_attend(q, k, v, row0, col0, *, causal, window, scale):
    """One (q-chunk, k-chunk) tile. q: (B,KV,G,qc,D), k/v: (B,KV,kc,D).
    Returns unnormalized (acc, m, l) contributions."""
    qc, kc = q.shape[3], k.shape[2]
    s = jnp.einsum("bkgqd,bksd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    row = row0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
    col = col0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 4)
    mask = jnp.ones(s.shape, bool)
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    return s, mask


def xla_flash(q, k, v, *, causal=True, window=None, scale=None,
              q_chunk=512, k_chunk=1024, kv_valid=None):
    """q: (B, H, Sq, D); k/v: (B, KVH, Sk, D). Queries tail-aligned to keys.

    kv_valid: optional (Sk,) bool — extra key-slot mask (ragged caches)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    offset = Sk - Sq

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq = -(-Sq // q_chunk)
    qpad = nq * q_chunk - Sq
    qg = q.reshape(B, KV, G, Sq, D)
    if qpad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, qpad), (0, 0)))

    use_window_slice = (window is not None
                        and window + q_chunk < Sk - k_chunk // 2)

    def one_q_chunk(qi):
        qs = qi * q_chunk
        qtile = jax.lax.dynamic_slice_in_dim(qg, qs, q_chunk, axis=3)
        row0 = qs + offset

        if use_window_slice:
            ws = min(Sk, window + q_chunk)
            start = jnp.clip(row0 - window + 1, 0, Sk - ws)
            ktile = jax.lax.dynamic_slice_in_dim(k, start, ws, axis=2)
            vtile = jax.lax.dynamic_slice_in_dim(v, start, ws, axis=2)
            s, mask = _chunk_attend(qtile, ktile, vtile, row0, start,
                                    causal=causal, window=window, scale=scale)
            if kv_valid is not None:
                valid = jax.lax.dynamic_slice_in_dim(kv_valid, start, ws, 0)
                mask &= valid[None, None, None, None, :]
            s = jnp.where(mask, s, NEG)
            m = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - m) * mask
            l = jnp.sum(p, axis=-1, keepdims=True)
            o = jnp.einsum("bkgqs,bksd->bkgqd", p, vtile.astype(jnp.float32))
            return jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)

        nk = -(-Sk // k_chunk)
        kpad = nk * k_chunk - Sk
        # pad keys so chunk slicing never clamps (clamped starts would
        # mislabel columns and double-count tail keys)
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0))) if kpad else k
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0))) if kpad else v

        @jax.checkpoint
        def kstep(carry, ki):
            m_prev, l_prev, acc = carry
            ks = ki * k_chunk
            ktile = jax.lax.dynamic_slice_in_dim(kp, ks, k_chunk, axis=2)
            vtile = jax.lax.dynamic_slice_in_dim(vp, ks, k_chunk, axis=2)
            s, mask = _chunk_attend(qtile, ktile, vtile, row0, ks,
                                    causal=causal, window=window, scale=scale)
            col = ks + jax.lax.broadcasted_iota(jnp.int32, s.shape, 4)
            mask &= col < Sk  # k padding from ragged last chunk
            if kv_valid is not None:
                vpad = jnp.pad(kv_valid, (0, kpad)) if kpad else kv_valid
                valid = jax.lax.dynamic_slice_in_dim(vpad, ks, k_chunk, 0)
                mask &= valid[None, None, None, None, :]
            s = jnp.where(mask, s, NEG)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(s - m_cur) * mask
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bkgqs,bksd->bkgqd", p,
                                           vtile.astype(jnp.float32))
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((B, KV, G, q_chunk, 1), NEG, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kstep, (m0, l0, a0), jnp.arange(nk))
        return jnp.where(l > 0, acc / jnp.maximum(l, 1e-30), 0.0)

    if nq == 1:
        out = one_q_chunk(jnp.asarray(0))[:, :, :, None]      # (B,KV,G,1,qc,D)
    else:
        out = jax.lax.map(one_q_chunk, jnp.arange(nq))        # (nq,B,KV,G,qc,D)
        out = jnp.moveaxis(out, 0, 3)                         # (B,KV,G,nq,qc,D)
    out = out.reshape(B, H, nq * q_chunk, D)[:, :, :Sq]
    return out.astype(q.dtype)


def attend(q, k, v, *, causal=True, window=None, scale=None, impl="xla",
           kv_valid=None):
    """Dispatch: XLA chunked flash (default / dry-run) or Pallas kernel."""
    if impl == "xla":
        return xla_flash(q, k, v, causal=causal, window=window, scale=scale,
                         kv_valid=kv_valid)
    KV = k.shape[1]
    H = q.shape[1]
    if H != KV:  # kernel is MHA-layout; expand kv (TPU path; G small)
        k = jnp.repeat(k, H // KV, axis=1)
        v = jnp.repeat(v, H // KV, axis=1)
    return flash_attention(q, k, v, causal=causal, window=window, scale=scale,
                           impl=impl)


# ---------------------------------------------------------------------------
# attention layer (params + cache)
# ---------------------------------------------------------------------------

# KV cache is a plain dict {"k": (B, KV, cache_len, hd), "v": ...} so layer
# caches stack cleanly under lax.scan.  Whether the cache is a ring buffer
# (cache_len == window < max_len) is *static* model-level information passed
# as an argument; the decode step counter is a single scalar owned by the
# model, not per-layer state.


def attn_init(key, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": layers.linear_init(ks[0], d, H * hd, use_bias=cfg.use_bias,
                                 dtype=dt, axes=("embed", "qkv")),
        "wk": layers.linear_init(ks[1], d, KV * hd, use_bias=cfg.use_bias,
                                 dtype=dt, axes=("embed", "qkv")),
        "wv": layers.linear_init(ks[2], d, KV * hd, use_bias=cfg.use_bias,
                                 dtype=dt, axes=("embed", "qkv")),
        "wo": layers.linear_init(ks[3], H * hd, d, use_bias=cfg.use_bias,
                                 dtype=dt, axes=("qkv", "embed")),
    }


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    q = layers.linear(p["wq"], x, cdt).reshape(B, S, H, hd)
    k = layers.linear(p["wk"], x, cdt).reshape(B, S, KV, hd)
    v = layers.linear(p["wv"], x, cdt).reshape(B, S, KV, hd)
    if cfg.pos == "rope":
        q = layers.apply_rope(q.swapaxes(1, 2), positions[:, None, :],
                              theta=cfg.rope_theta,
                              rope_fraction=cfg.rope_fraction).swapaxes(1, 2)
        k = layers.apply_rope(k.swapaxes(1, 2), positions[:, None, :],
                              theta=cfg.rope_theta,
                              rope_fraction=cfg.rope_fraction).swapaxes(1, 2)
    # (B, heads, S, hd)
    return q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2)


def attn_apply(p, x, cfg: ModelConfig, *, positions, impl="xla"):
    """Training / prefill path.  x: (B, S, d) -> (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = shard(q, ("sub_batch", "heads", "seq", None))
    o = attend(q, k, v, causal=True, window=cfg.window, impl=impl)
    o = o.swapaxes(1, 2).reshape(B, S, cfg.num_heads * cfg.head_dim)
    return layers.linear(p["wo"], o, jnp.dtype(cfg.compute_dtype))


def cache_is_ring(cfg: ModelConfig, max_len: int) -> bool:
    return cfg.window is not None and cfg.window < max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Ring buffer of size window for SWA archs, else full-length cache."""
    clen = cfg.window if cache_is_ring(cfg, max_len) else max_len
    shape = (batch, cfg.num_kv_heads, clen, cfg.head_dim)
    zeros = shard(jnp.zeros(shape, dtype),
                  ("sub_batch", "kv_heads", "cache_seq", "head_dim"))
    return {"k": zeros, "v": zeros}


def attn_decode(p, x, cfg: ModelConfig, cache: dict, *, step, ring: bool):
    """One-token decode.  x: (B, 1, d); step: () int32 absolute position."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(step[None, None], (B, 1))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)  # (B,*,1,hd)

    clen = cache["k"].shape[2]
    slot = jax.lax.rem(step, clen) if ring else jnp.minimum(step, clen - 1)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=2)

    idx = jnp.arange(clen)
    filled = ((idx <= step) | (step >= clen)) if ring else (idx <= step)
    qg = q.reshape(B, KV, H // KV, 1, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    s = jnp.where(filled[None, None, None, None, :], s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    out = layers.linear(p["wo"], o, jnp.dtype(cfg.compute_dtype))
    return out, {"k": k, "v": v}
