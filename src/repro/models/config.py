"""Model configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
transformer assembler (:mod:`repro.models.transformer`) is driven entirely
by this config, so an architecture is *data*, not code.

``block_pattern`` is the repeating unit of the layer stack (e.g.
``("attn",)`` for a llama-style dense model, ``("rglru", "rglru", "attn")``
for RecurrentGemma's 2:1 temporal-mixing pattern, or an 8-long mLSTM/sLSTM
period for xLSTM).  The stack is ``num_layers`` entries of the cycled
pattern; full periods are executed under one ``lax.scan`` over stacked
params (keeps HLO size O(1) in depth — essential for the 40-config
multi-pod dry-run), with any non-period tail applied unstacked.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoESettings:
    """Mixture-of-Experts block settings.

    ``d_expert`` is the per-expert FFN width (deepseek's fine-grained experts
    use a small one).  ``num_shared`` experts run densely for every token
    (deepseek-moe).  Routing is top-k softmax with capacity-based token
    dropping (GShard/Switch style) implemented via sort+scatter, so the
    FLOPs are the *active* FLOPs, not num_experts x dense.
    """
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance loss weight
    router_z_weight: float = 1e-3     # router logit z-loss


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    block_pattern: tuple[str, ...] = ("attn",)
    moe: MoESettings | None = None
    moe_skip_first: bool = False      # deepseek: layer 0 keeps a dense FFN
    dense_d_ff_first: int = 0         # ... of this width
    window: int | None = None         # sliding-window attention (Mixtral: 4096)
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0        # stablelm-2 rotates 25% of head_dim
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "silu"                 # silu | gelu
    gated_mlp: bool = True            # SwiGLU/GeGLU vs plain 2-layer MLP
    use_bias: bool = False
    tie_embeddings: bool = False
    pos: str = "rope"                 # rope | sinusoidal | none
    # multimodal stub frontends (the ONE sanctioned stub):
    frontend: str | None = None       # None | 'vision' | 'audio'
    num_prefix_embeds: int = 0        # patches / conditioning frames
    d_frontend: int = 0               # frontend embedding width
    # recurrent blocks:
    mlstm_proj_factor: float = 2.0    # mLSTM up-projection
    slstm_proj_factor: float = 1.3334 # sLSTM post-FFN factor (4/3)
    conv_width: int = 4               # short conv in rglru/mlstm blocks
    rglru_width: int = 0              # 0 -> d_model
    # numerics
    compute_dtype: str = "bfloat16"   # matmul/activation dtype
    param_dtype: str = "float32"
    logit_softcap: float = 0.0        # recurrentgemma uses 30.0
    # execution
    remat: bool = True                # checkpoint each block in training
    scan_layers: bool = True          # False: unroll the period stack —
    # used by the dry-run roofline pass because XLA's HloCostAnalysis counts
    # while-loop bodies ONCE (verified empirically); unrolling makes HLO
    # FLOPs/collectives exact per layer at the cost of HLO size.

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, \
            f"{self.name}: heads {self.num_heads} % kv {self.num_kv_heads}"

    # ---- derived structure -------------------------------------------------
    def layer_kinds(self) -> tuple[str, ...]:
        return tuple(self.block_pattern[i % len(self.block_pattern)]
                     for i in range(self.num_layers))

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_full_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def tail_kinds(self) -> tuple[str, ...]:
        return self.layer_kinds()[self.n_full_periods * self.period:]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return not (self.moe_skip_first and layer_idx == 0)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for roofline MODEL_FLOPS = 6*N*D) ----------------
    def param_count(self) -> int:
        """Analytic parameter count (matches init to <1%; exact in tests)."""
        from repro.models.transformer import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top_k + shared experts)."""
        from repro.models.transformer import count_params_analytic
        return count_params_analytic(self, active_only=True)
