"""Primitive layers: norms, linear, embeddings, rotary/sinusoidal positions.

Parameters are plain pytrees (nested dicts of jnp arrays); every layer is a
pair of functions  init(key, ...) -> params  and  apply(params, x, ...).
Sharding is injected through :func:`repro.dist.sharding.shard` logical-axis
constraints so the same model code runs single-host and on the pod mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    """He-style fan-in init (stddev = scale / sqrt(fan_in))."""
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale / max(fan_in, 1) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

def linear_init(key, d_in, d_out, *, use_bias=False, scale=1.0,
                dtype=jnp.float32, axes=("embed", "mlp")):
    p = {"w": shard(truncated_normal_init(key, (d_in, d_out), scale, dtype), axes)}
    if use_bias:
        p["b"] = shard(jnp.zeros((d_out,), dtype), axes[-1:])
    return p


def linear(p, x, compute_dtype=jnp.bfloat16):
    # bf16 operands, fp32 accumulator (PRECISION lint contract) — the
    # MXU-native layout; result is cast back to the compute dtype.
    y = jnp.matmul(x.astype(compute_dtype), p["w"].astype(compute_dtype),
                   preferred_element_type=jnp.float32).astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d, kind="rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab, d_model, dtype=jnp.float32):
    table = jax.random.normal(key, (vocab, d_model), jnp.float32)
    return {"table": shard((table * d_model ** -0.5).astype(dtype),
                           ("vocab", "embed"))}


def embed(p, ids, compute_dtype=jnp.bfloat16):
    return jnp.take(p["table"], ids, axis=0).astype(compute_dtype)


def unembed(p, x, compute_dtype=jnp.bfloat16):
    """Logits (tied or untied table passed in p); fp32 accumulation."""
    return jnp.matmul(x.astype(compute_dtype),
                      p["table"].T.astype(compute_dtype),
                      preferred_element_type=jnp.float32
                      ).astype(compute_dtype)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, rope_fraction=1.0, theta=10000.0):
    """Inverse frequencies for the rotated fraction of head_dim."""
    rot = int(head_dim * rope_fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)), rot


def apply_rope(x, positions, *, theta=10000.0, rope_fraction=1.0):
    """x: (..., seq, head_dim), positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv, rot = rope_freqs(head_dim, rope_fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv      # (..., seq, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(positions, d_model):
    """Classic transformer sinusoids. positions: (..., seq) -> (..., seq, d)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x, cap: float):
    """tanh soft-capping (recurrentgemma logits)."""
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "tanh": jnp.tanh}
