"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain 2-layer MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d = cfg.d_model
    d_ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {
        "up": layers.linear_init(ks[0], d, d_ff, use_bias=cfg.use_bias,
                                 dtype=dt, axes=("embed", "mlp")),
        "down": layers.linear_init(ks[1], d_ff, d, use_bias=cfg.use_bias,
                                   dtype=dt, axes=("mlp", "embed")),
    }
    if cfg.gated_mlp:
        p["gate"] = layers.linear_init(ks[2], d, d_ff, use_bias=cfg.use_bias,
                                       dtype=dt, axes=("embed", "mlp"))
    return p


def mlp_apply(p, x, cfg: ModelConfig):
    cdt = jnp.dtype(cfg.compute_dtype)
    act = layers.ACTS[cfg.act]
    h = layers.linear(p["up"], x, cdt)
    if "gate" in p:
        h = h * act(layers.linear(p["gate"], x, cdt))
    else:
        h = act(h)
    from repro.dist.sharding import shard
    h = shard(h, ("sub_batch", "seq", "mlp"))
    return layers.linear(p["down"], h, cdt)
