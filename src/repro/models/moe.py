"""Mixture-of-Experts block: top-k softmax routing with capacity dropping.

Dispatch strategy (production, GShard/Switch-style, but sort-based):
  1. Router logits -> top_k (expert, prob) per token.
  2. Flatten to T*k slots, compute each slot's *position within its expert*
     via a sorted segment-cumsum; slots whose position exceeds capacity
     C = ceil(T * k / E * capacity_factor) are dropped (token keeps its
     other experts / the residual path).
  3. Scatter surviving slots into an (E, C, d) buffer, run the expert FFNs
     as one batched einsum — true active-FLOPs, NOT num_experts x dense and
     NOT a (T, E, C) one-hot dispatch matmul (which would dominate HLO
     FLOPs and wreck the roofline's useful-compute ratio).
  4. Gather back with combine weights; add shared experts densely
     (deepseek-moe's 2 shared experts).

Losses: load-balance auxiliary loss (Switch eq. 4) + router z-loss,
returned as a dict for the train loop to weigh in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers
from repro.models.config import ModelConfig


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    d_e = m.d_expert or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)

    def expert_bank(k, n, d_in, d_out, axes):
        w = layers.truncated_normal_init(k, (n, d_in, d_out), 1.0, dt)
        return shard(w, axes)

    p = {
        "router": layers.linear_init(ks[0], d, m.num_experts, dtype=dt,
                                     axes=("embed", None)),
        # routed experts: (E, d, d_e) — sharding axes per-arch:
        # deepseek shards E ('experts'->model), mixtral shards d_e.
        "w_up": expert_bank(ks[1], m.num_experts, d, d_e,
                            ("experts", "embed", "expert_mlp")),
        "w_gate": expert_bank(ks[2], m.num_experts, d, d_e,
                              ("experts", "embed", "expert_mlp")),
        "w_down": expert_bank(ks[3], m.num_experts, d_e, d,
                              ("experts", "expert_mlp", "embed")),
    }
    if m.num_shared:
        p["shared"] = {
            "w_up": expert_bank(ks[4], m.num_shared, d, d_e,
                                (None, "embed", "expert_mlp")),
            "w_gate": expert_bank(jax.random.fold_in(ks[4], 1), m.num_shared,
                                  d, d_e, (None, "embed", "expert_mlp")),
            "w_down": expert_bank(jax.random.fold_in(ks[4], 2), m.num_shared,
                                  d_e, d, (None, "expert_mlp", "embed")),
        }
    return p


def _expert_ffn(w_up, w_gate, w_down, x, cfg: ModelConfig):
    """Batched expert FFN.  x: (E, C, d) with per-expert weight banks."""
    cdt = jnp.dtype(cfg.compute_dtype)
    act = layers.ACTS[cfg.act]

    def mm(sub, a, b):
        # bf16 operands, fp32 accumulation (PRECISION lint contract)
        return jnp.einsum(sub, a, b,
                          preferred_element_type=jnp.float32).astype(cdt)

    xc = x.astype(cdt)
    up = mm("ecd,edf->ecf", xc, w_up.astype(cdt))
    gate = act(mm("ecd,edf->ecf", xc, w_gate.astype(cdt)))
    return mm("ecf,efd->ecd", up * gate, w_down.astype(cdt))


def moe_apply(p, x, cfg: ModelConfig, *, capacity: int | None = None):
    """x: (B, S, d) -> (y, losses)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    cdt = jnp.dtype(cfg.compute_dtype)
    xt = x.reshape(T, d)

    logits = layers.linear(p["router"], xt, jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # ---- losses ----
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), 0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob) * m.router_aux_weight
    zloss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * m.router_z_weight
    losses = {"moe_aux": aux, "moe_z": zloss}

    # ---- capacity dispatch via sort ----
    cap = capacity or int(-(-T * k // E) * m.capacity_factor)
    cap = max(8, min(cap, T))
    flat_e = top_e.reshape(T * k)                                  # slot -> expert
    flat_p = top_p.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e)                                    # stable
    se, sp, stok = flat_e[order], flat_p[order], flat_tok[order]
    # position within expert segment:
    seg_start = jnp.searchsorted(se, jnp.arange(E))                # (E,)
    pos = jnp.arange(T * k) - seg_start[se]
    # 3D scatter keeps the (E, cap, d) buffer shardable over the expert
    # axis (a flat E*cap buffer would break expert parallelism and force
    # GSPMD to replicate the dispatch); slots past capacity scatter out of
    # bounds and are dropped by mode='drop'.
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                              # oob => drop

    # Dispatch/combine scatter-adds accumulate in fp32 (PRECISION lint
    # contract — the combine genuinely collides: k slots per token).
    buf = jnp.zeros((E, cap, d), jnp.float32)
    buf = buf.at[se, pos_c].add(xt[stok].astype(jnp.float32), mode="drop")
    buf = shard(buf.astype(cdt), ("experts", None, "embed"))

    y_exp = _expert_ffn(p["w_up"], p["w_gate"], p["w_down"], buf, cfg)

    gathered = y_exp.at[se, jnp.minimum(pos_c, cap - 1)].get(
        mode="fill", fill_value=0.0) * (keep * sp)[:, None]
    y = jnp.zeros((T, d), jnp.float32).at[stok].add(
        gathered.astype(jnp.float32))

    if "shared" in p:
        sh = p["shared"]
        xs = jnp.broadcast_to(xt[None], (m.num_shared, T, d))
        y_sh = _expert_ffn(sh["w_up"], sh["w_gate"], sh["w_down"], xs, cfg)
        y = y + jnp.sum(y_sh.astype(jnp.float32), axis=0)

    return y.reshape(B, S, d).astype(x.dtype), losses
