"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_r x_t)                      (recurrence gate)
    i_t = sigmoid(W_i x_t)                      (input gate)
    log a_t = -c * softplus(Lambda) * r_t       (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The linear recurrence is computed with ``jax.lax.associative_scan`` over
the sequence (log-depth on TPU); decode is a single fused step.  The block
wraps the RG-LRU between an input projection (two branches: recurrent and
GeLU gate, Griffin-style), a short causal depthwise conv on the recurrent
branch, and an output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.ssm import conv_apply, conv_init

_C = 8.0


def rglru_init(key, d_rnn, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    # Lambda init so that a ~ Uniform(0.9, 0.999)^c at r=1 (paper App. A)
    u = jax.random.uniform(ks[0], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))     # inverse softplus of -log u
    return {
        "lam": shard(lam.astype(dtype), ("state",)),
        "wr": layers.linear_init(ks[1], d_rnn, d_rnn, dtype=dtype,
                                 axes=("state", "state")),
        "wi": layers.linear_init(ks[2], d_rnn, d_rnn, dtype=dtype,
                                 axes=("state", "state")),
    }


def rglru_apply(p, x, h0=None):
    """x: (B, S, d_rnn) fp32; h0: (B, d_rnn). Returns (y, h_last)."""
    x = x.astype(jnp.float32)
    B, S, d = x.shape
    r = jax.nn.sigmoid(layers.linear(p["wr"], x, jnp.float32))
    i = jax.nn.sigmoid(layers.linear(p["wi"], x, jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * x)
    if h0 is not None:
        # fold the carried state in as a virtual step 0: h_0 contributes
        # a_1..t * h0; implement by prepending (a=1?) — simpler: add after scan
        pass

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h + a_cum * h0[:, None, :]
    return h, h[:, -1, :]


def rglru_block_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_rnn = cfg.rglru_width or d
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        "in_rec": layers.linear_init(ks[0], d, d_rnn, dtype=dt,
                                     axes=("embed", "state")),
        "in_gate": layers.linear_init(ks[1], d, d_rnn, dtype=dt,
                                      axes=("embed", "state")),
        "conv": conv_init(ks[2], cfg.conv_width, d_rnn, dt),
        "rglru": rglru_init(ks[3], d_rnn, dt),
        "out": layers.linear_init(ks[4], d_rnn, d, dtype=dt,
                                  axes=("state", "embed")),
    }


def rglru_block_apply(p, x, cfg: ModelConfig, state=None):
    """x: (B,S,d) -> (y, state). state = (h_last, conv_state)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    h0, conv_state = state if state is not None else (None, None)
    rec = layers.linear(p["in_rec"], x, cdt)
    gate = jax.nn.gelu(layers.linear(p["in_gate"], x, cdt))
    rec, conv_state = conv_apply(p["conv"], rec, conv_state)
    h, h_last = rglru_apply(p["rglru"], rec, h0)
    y = layers.linear(p["out"], h.astype(cdt) * gate, cdt)
    return y, (h_last, conv_state)
