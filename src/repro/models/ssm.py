"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential) — Beck et al., arXiv:2405.04517.

mLSTM is a linear-attention-style cell with exponential gating:

    C_t = f_t C_{t-1} + i_t k_t v_t^T,   n_t = f_t n_{t-1} + i_t k_t,
    h_t = (C_t^T q_t) / max(|n_t . q_t|, exp(-m_t)),

stabilized by the running log-scale m_t (gates live in log space).  We run
it **chunkwise**: within a chunk of length c the contributions are computed
as a (c x c) masked parallel form (quadratic in c, MXU-friendly); across
chunks a ``lax.scan`` carries (C, n, m).  A step-by-step sequential
reference (``mlstm_sequential``) is kept for equivalence tests.

sLSTM has per-unit scalar memory with recurrent gate connections
(block-diagonal per head), which makes it inherently sequential — a
``lax.scan`` over time; this is the paper's trade-off, and why xLSTM-1.3b
interleaves 7 mLSTM : 1 sLSTM.

TPU adaptation notes (DESIGN.md §2): chunk size 256 keeps the quadratic
intra-chunk work MXU-aligned; head dims shard over the ``model`` axis
(heads are independent); the recurrent state is the decode cache, O(1) in
sequence length — this is why xlstm-1.3b runs ``long_500k`` natively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import layers
from repro.models.config import ModelConfig

NEG = -1e30


# ---------------------------------------------------------------------------
# causal depthwise conv (short; used by mLSTM and RG-LRU blocks)
# ---------------------------------------------------------------------------

def conv_init(key, width, d, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (width, d), jnp.float32)
                  * (1.0 / width)).astype(dtype),
            "b": jnp.zeros((d,), dtype)}


def conv_apply(p, x, state=None):
    """x: (B, S, d).  state: (B, width-1, d) trailing context for decode.
    Returns (y, new_state)."""
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(w[j] * jax.lax.dynamic_slice_in_dim(
        xp, (width - 1) - j, x.shape[1], axis=1) for j in range(width))
    y = y + p["b"].astype(x.dtype)
    return y, xp[:, -(width - 1):, :]


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------

def _mlstm_chunk(q, k, v, li, lf, state):
    """One chunk, parallel form.  q,k: (B,H,c,dk), v: (B,H,c,dv),
    li/lf: (B,H,c) log input/forget gates.  state = (C, n, m)."""
    C, n, m = state                      # (B,H,dk,dv), (B,H,dk), (B,H)
    c = q.shape[2]
    a = jnp.cumsum(lf, axis=-1)                       # (B,H,c) inclusive
    # D_ts = a_t - a_s + li_s  for s <= t
    D = a[..., :, None] - a[..., None, :] + li[..., None, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri, D, NEG)
    m_intra = jnp.max(D, axis=-1)                     # (B,H,c)
    m_inter = a + m[..., None]                        # state carries scale m
    m_t = jnp.maximum(m_intra, m_inter)

    dots = jnp.einsum("bhtd,bhsd->bhts", q, k)
    W = jnp.exp(D - m_t[..., None]) * jnp.where(tri, 1.0, 0.0)
    num = jnp.einsum("bhts,bhsv->bhtv", W * dots, v)
    den = jnp.einsum("bhts,bhts->bht", W, dots)

    scale = jnp.exp(m_inter - m_t)                    # (B,H,c)
    num = num + scale[..., None] * jnp.einsum("bhtd,bhdv->bhtv", q, C)
    den = den + scale * jnp.einsum("bhtd,bhd->bht", q, n)

    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # chunk-end state update
    a_c = a[..., -1]                                  # (B,H)
    m_new = jnp.maximum(a_c + m, jnp.max(a_c[..., None] - a + li, axis=-1))
    w_state = jnp.exp(a_c[..., None] - a + li - m_new[..., None])  # (B,H,c)
    C_new = (jnp.exp(a_c + m - m_new)[..., None, None] * C
             + jnp.einsum("bhs,bhsd,bhsv->bhdv", w_state, k, v))
    n_new = (jnp.exp(a_c + m - m_new)[..., None] * n
             + jnp.einsum("bhs,bhsd->bhd", w_state, k))
    return h, (C_new, n_new, m_new)


def mlstm_parallel(q, k, v, li, lf, state, *, chunk=256):
    """Chunkwise mLSTM over a full sequence.  Shapes as in _mlstm_chunk with
    seq len S; pads S to a chunk multiple.  Returns (h, final_state)."""
    B, H, S, dk = q.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        zq = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 3))
        q, k, v = zq(q), zq(k), zq(v)
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)), constant_values=NEG)
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))  # lf=0 => identity decay
    nc = q.shape[2] // c

    def body(st, xs):
        qc, kc, vc, lic, lfc = xs
        h, st = _mlstm_chunk(qc, kc, vc, lic, lfc, st)
        return st, h

    split = lambda x: jnp.moveaxis(
        x.reshape(B, H, nc, c, *x.shape[3:]), 2, 0)
    st, hs = jax.lax.scan(body, state,
                          (split(q), split(k), split(v), split(li), split(lf)))
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, nc * c, -1)[:, :, :S]
    return h, st


def mlstm_sequential(q, k, v, li, lf, state):
    """Step-by-step oracle for tests."""
    def step(st, xs):
        C, n, m = st
        qt, kt, vt, lit, lft = xs
        m_new = jnp.maximum(lft + m, lit)
        fp = jnp.exp(lft + m - m_new)
        ip = jnp.exp(lit - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhd,bhdv->bhv", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (q, k, v, li, lf))
    st, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 2), st


def mlstm_state_init(batch, heads, dk, dv, dtype=jnp.float32):
    return (shard(jnp.zeros((batch, heads, dk, dv), dtype),
                  ("sub_batch", "heads", None, None)),
            shard(jnp.zeros((batch, heads, dk), dtype),
                  ("sub_batch", "heads", None)),
            jnp.full((batch, heads), -1e30, dtype))


# ---------------------------------------------------------------------------
# mLSTM block (up-proj, conv, heads, gating, down-proj)
# ---------------------------------------------------------------------------

MLSTM_QKV_BLOCK = 4   # official xLSTM qkv_proj_blocksize: block-diagonal qkv


def _blockdiag_init(key, d, bs, dtype):
    nb = d // bs
    w = layers.truncated_normal_init(key, (nb, bs, bs), 1.0, dtype)
    return {"w": shard(w, ("state", None, None))}


def _blockdiag_apply(p, x, cdt):
    """Block-diagonal linear: x (..., d) with (nb, bs, bs) blocks."""
    nb, bs, _ = p["w"].shape
    # fp32 accumulation on the bf16 block contraction (PRECISION lint)
    y = jnp.einsum("...nb,nbc->...nc", x.reshape(*x.shape[:-1], nb, bs)
                   .astype(cdt), p["w"].astype(cdt),
                   preferred_element_type=jnp.float32).astype(cdt)
    return y.reshape(*x.shape[:-1], nb * bs)


def mlstm_block_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    bs = MLSTM_QKV_BLOCK
    return {
        "up": layers.linear_init(ks[0], d, 2 * d_in, dtype=dt,
                                 axes=("embed", "state")),
        "conv": conv_init(ks[1], cfg.conv_width, d_in, dt),
        "wq": _blockdiag_init(ks[2], d_in, bs, dt),
        "wk": _blockdiag_init(ks[3], d_in, bs, dt),
        "wv": _blockdiag_init(ks[4], d_in, bs, dt),
        "wif": layers.linear_init(ks[5], d_in, 2 * H, dtype=dt,
                                  axes=("state", None)),
        "norm": layers.norm_init(d_in, "rmsnorm", dt),
        "down": layers.linear_init(ks[6], d_in, d, dtype=dt,
                                   axes=("state", "embed")),
    }


def _mlstm_qkvif(p, x, cfg, conv_state):
    B, S, _ = x.shape
    H = cfg.num_heads
    cdt = jnp.dtype(cfg.compute_dtype)
    d_in = p["conv"]["w"].shape[1]
    up = layers.linear(p["up"], x, cdt)
    xm, z = up[..., :d_in], up[..., d_in:]
    xc, conv_state = conv_apply(p["conv"], xm, conv_state)
    xc = jax.nn.silu(xc)
    dk = d_in // H
    heads = lambda t: t.reshape(B, S, H, dk).swapaxes(1, 2)
    q = heads(_blockdiag_apply(p["wq"], xc, cdt)).astype(jnp.float32)
    k = heads(_blockdiag_apply(p["wk"], xc, cdt)).astype(jnp.float32) * dk ** -0.5
    v = heads(_blockdiag_apply(p["wv"], xm, cdt)).astype(jnp.float32)
    ifg = layers.linear(p["wif"], xc, jnp.float32)
    li = ifg[..., :H].swapaxes(1, 2)                  # (B,H,S) log input gate
    lf = jax.nn.log_sigmoid(ifg[..., H:]).swapaxes(1, 2)
    return q, k, v, li, lf, z, conv_state


def mlstm_block_apply(p, x, cfg: ModelConfig, state=None, *, chunk=256):
    """x: (B,S,d) -> (y, state).  state=(cell_state, conv_state) or None."""
    B, S, d = x.shape
    H = cfg.num_heads
    d_in = p["conv"]["w"].shape[1]
    cell, conv_state = state if state is not None else (
        mlstm_state_init(B, H, d_in // H, d_in // H), None)
    q, k, v, li, lf, z, conv_state = _mlstm_qkvif(p, x, cfg, conv_state)
    h, cell = mlstm_parallel(q, k, v, li, lf, cell, chunk=chunk)
    h = h.swapaxes(1, 2).reshape(B, S, d_in).astype(x.dtype)
    h = layers.apply_norm(p["norm"], h, "rmsnorm")
    h = h * jax.nn.silu(z.astype(h.dtype))
    y = layers.linear(p["down"], h, jnp.dtype(cfg.compute_dtype))
    return y, (cell, conv_state)


def mlstm_block_decode(p, x, cfg: ModelConfig, state):
    """One-token step: reuse the chunk path with S=1 (exact)."""
    return mlstm_block_apply(p, x, cfg, state, chunk=1)


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_block_init(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    d_ff = int(cfg.slstm_proj_factor * d)
    return {
        "wx": layers.linear_init(ks[0], d, 4 * d, dtype=dt,
                                 axes=("embed", "state")),   # z,i,f,o
        "r": shard(layers.truncated_normal_init(ks[1], (4, H, dh, dh), 1.0, dt),
                   (None, "heads", None, None)),
        "norm": layers.norm_init(d, "rmsnorm", dt),
        "ff_up": layers.linear_init(ks[2], d, d_ff, dtype=dt,
                                    axes=("embed", "mlp")),
        "ff_gate": layers.linear_init(ks[3], d, d_ff, dtype=dt,
                                      axes=("embed", "mlp")),
        "ff_down": layers.linear_init(ks[4], d_ff, d, dtype=dt,
                                      axes=("mlp", "embed")),
    }


def slstm_state_init(batch, heads, dh, dtype=jnp.float32):
    z = jnp.zeros((batch, heads, dh), dtype)
    return (z, z + 1e-6, jnp.full_like(z, -1e30), z)  # c, n, m, h_prev


def slstm_cell_scan(gx, r, state):
    """gx: (B, S, 4, H, dh) input-side gate preactivations."""
    def step(st, g):
        c, n, m, h = st
        rec = jnp.einsum("ghde,bhe->bghd", r.astype(jnp.float32), h)
        zt = jnp.tanh(g[:, 0] + rec[:, 0])
        li = g[:, 1] + rec[:, 1]
        lf = jax.nn.log_sigmoid(g[:, 2] + rec[:, 2])
        ot = jax.nn.sigmoid(g[:, 3] + rec[:, 3])
        m_new = jnp.maximum(lf + m, li)
        fp, ip = jnp.exp(lf + m - m_new), jnp.exp(li - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        h = ot * c / jnp.maximum(n, jnp.exp(-m_new))
        return (c, n, m_new, h), h

    st, hs = jax.lax.scan(step, state, jnp.moveaxis(gx, 1, 0))
    return jnp.moveaxis(hs, 0, 1), st        # (B,S,H,dh)


def slstm_block_apply(p, x, cfg: ModelConfig, state=None):
    B, S, d = x.shape
    H = cfg.num_heads
    dh = d // H
    if state is None:
        state = slstm_state_init(B, H, dh)
    gx = layers.linear(p["wx"], x, jnp.float32).reshape(B, S, 4, H, dh)
    h, state = slstm_cell_scan(gx, p["r"], state)
    h = layers.apply_norm(p["norm"], h.reshape(B, S, d).astype(x.dtype),
                          "rmsnorm")
    cdt = jnp.dtype(cfg.compute_dtype)
    y = layers.linear(p["ff_down"],
                      layers.linear(p["ff_up"], h, cdt)
                      * jax.nn.silu(layers.linear(p["ff_gate"], h, cdt)), cdt)
    return y, state
