"""Transformer assembler: config -> init / forward / loss / decode.

Layer stack execution
---------------------
``cfg.block_pattern`` defines a repeating period (e.g. ``('rglru','rglru',
'attn')``).  The stack splits into:

  head   — ``cfg.moe_skip_first`` puts layer 0 (deepseek's dense-FFN layer)
           outside the scan,
  body   — all full periods, executed as ONE ``lax.scan`` over stacked
           params (HLO size O(period), independent of depth: this is what
           keeps 40 multi-pod dry-run compiles tractable),
  tail   — the non-period remainder (e.g. recurrentgemma's 38 = 12*3 + 2),
           applied unstacked.

Blocks are pre-norm residual: ``x += mixer(norm(x))``; attention blocks are
followed by a second ``x += ffn(norm(x))`` (dense MLP or MoE); recurrent
blocks (mlstm/slstm) carry their own internal FFN per the xLSTM design when
``d_ff == 0``, otherwise they too get the ffn.

Caches mirror the head/body/tail structure; the body cache is a stacked
pytree scanned alongside the params.  The decode step counter is one scalar.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import (attention, layers, mlp as mlp_lib, moe as moe_lib,
                          rglru as rglru_lib, ssm)
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return kind in ("attn", "rglru") and (cfg.d_ff > 0 or cfg.moe is not None)


def block_init(key, cfg: ModelConfig, kind: str, layer_idx: int):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p: dict[str, Any] = {"norm1": layers.norm_init(cfg.d_model, cfg.norm, dt)}
    if kind == "attn":
        p["mixer"] = attention.attn_init(ks[0], cfg)
    elif kind == "mlstm":
        p["mixer"] = ssm.mlstm_block_init(ks[0], cfg)
    elif kind == "slstm":
        p["mixer"] = ssm.slstm_block_init(ks[0], cfg)
    elif kind == "rglru":
        p["mixer"] = rglru_lib.rglru_block_init(ks[0], cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if _has_ffn(cfg, kind):
        p["norm2"] = layers.norm_init(cfg.d_model, cfg.norm, dt)
        if cfg.is_moe_layer(layer_idx):
            p["ffn"] = moe_lib.moe_init(ks[1], cfg)
        else:
            d_ff = cfg.dense_d_ff_first if (cfg.moe_skip_first
                                            and layer_idx == 0) else cfg.d_ff
            p["ffn"] = mlp_lib.mlp_init(ks[1], cfg, d_ff=d_ff)
    return p


def block_apply(p, x, cfg: ModelConfig, kind: str, *, positions,
                is_moe: bool, cache=None, decode=False, step=None,
                ring=False, attn_impl="xla"):
    """Returns (x, new_cache, aux_losses)."""
    h = layers.apply_norm(p["norm1"], x, cfg.norm)
    new_cache = cache
    if kind == "attn":
        if decode:
            out, new_cache = attention.attn_decode(p["mixer"], h, cfg, cache,
                                                   step=step, ring=ring)
        else:
            out = attention.attn_apply(p["mixer"], h, cfg,
                                       positions=positions, impl=attn_impl)
    elif kind == "mlstm":
        out, new_cache = ssm.mlstm_block_apply(p["mixer"], h, cfg, cache,
                                               chunk=1 if decode else 256)
    elif kind == "slstm":
        out, new_cache = ssm.slstm_block_apply(p["mixer"], h, cfg, cache)
    elif kind == "rglru":
        out, new_cache = rglru_lib.rglru_block_apply(p["mixer"], h, cfg, cache)
    else:
        raise ValueError(kind)
    x = x + out.astype(x.dtype)

    losses = {}
    if "ffn" in p:
        h = layers.apply_norm(p["norm2"], x, cfg.norm)
        if is_moe:
            out, losses = moe_lib.moe_apply(p["ffn"], h, cfg)
        else:
            out = mlp_lib.mlp_apply(p["ffn"], h, cfg)
        x = x + out.astype(x.dtype)
    return x, new_cache, losses


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    if kind == "attn":
        return attention.init_cache(cfg, batch, max_len, dtype)
    if kind == "mlstm":
        d_in = int(cfg.mlstm_proj_factor * cfg.d_model)
        H = cfg.num_heads
        return (ssm.mlstm_state_init(batch, H, d_in // H, d_in // H),
                jnp.zeros((batch, cfg.conv_width - 1, d_in), dtype))
    if kind == "slstm":
        return ssm.slstm_state_init(batch, cfg.num_heads,
                                    cfg.d_model // cfg.num_heads)
    if kind == "rglru":
        d_rnn = cfg.rglru_width or cfg.d_model
        return (jnp.zeros((batch, d_rnn), jnp.float32),
                jnp.zeros((batch, cfg.conv_width - 1, d_rnn), dtype))
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack layout
# ---------------------------------------------------------------------------

def stack_layout(cfg: ModelConfig):
    """-> (head_kinds, n_periods, period_kinds, tail_kinds) with layer idx."""
    kinds = cfg.layer_kinds()
    off = 1 if cfg.moe_skip_first else 0
    head = tuple((i, kinds[i]) for i in range(off))
    body_layers = len(kinds) - off
    period = cfg.period
    n_periods = body_layers // period
    body_start = off
    tail_start = off + n_periods * period
    period_kinds = tuple(kinds[body_start:body_start + period])
    tail = tuple((i, kinds[i]) for i in range(tail_start, len(kinds)))
    return head, n_periods, period_kinds, body_start, tail


def init_params(key, cfg: ModelConfig):
    head, n_periods, period_kinds, body_start, tail = stack_layout(cfg)
    k_embed, k_head, k_body, k_tail, k_fe, k_out = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)

    params: dict[str, Any] = {
        "embed": layers.embedding_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": layers.norm_init(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.embedding_init(k_out, cfg.vocab_size,
                                                  cfg.d_model, dt)
    if cfg.frontend is not None:
        ks = jax.random.split(k_fe, 2)
        params["frontend"] = {
            "proj1": layers.linear_init(ks[0], cfg.d_frontend, cfg.d_model,
                                        dtype=dt, axes=(None, "embed")),
            "proj2": layers.linear_init(ks[1], cfg.d_model, cfg.d_model,
                                        dtype=dt, axes=("embed", "embed")),
        }

    params["head"] = [block_init(jax.random.fold_in(k_head, i), cfg, kind, i)
                      for i, kind in head]

    if n_periods > 0:
        def one_period(k):
            kk = jax.random.split(k, len(period_kinds))
            # layer_idx within body: any body layer works for is_moe/shape
            return [block_init(kk[j], cfg, kind, body_start + j)
                    for j, kind in enumerate(period_kinds)]
        period_keys = jax.random.split(k_body, n_periods)
        # python loop + tree-stack (not vmap: sharding constraints inside
        # init lack batching rules); init HLO stays O(n_periods), forward
        # HLO stays O(1) via the scan.
        periods = [one_period(k) for k in period_keys]
        params["body"] = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
    else:
        params["body"] = None

    params["tail"] = [block_init(jax.random.fold_in(k_tail, i), cfg, kind, i)
                      for i, kind in tail]
    return params


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    head, n_periods, period_kinds, body_start, tail = stack_layout(cfg)
    caches: dict[str, Any] = {
        "head": [block_cache_init(cfg, kind, batch, max_len, dtype)
                 for _, kind in head],
        "tail": [block_cache_init(cfg, kind, batch, max_len, dtype)
                 for _, kind in tail],
    }
    if n_periods > 0:
        one = [block_cache_init(cfg, kind, batch, max_len, dtype)
               for kind in period_kinds]
        caches["body"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one)
    else:
        caches["body"] = None
    return caches


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token (+ frontend prefix) embedding.  Returns (x, positions, loss_mask)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    x = layers.embed(params["embed"], tokens, cdt)
    loss_mask = batch.get("loss_mask")
    if cfg.frontend is not None and "prefix_embeds" in batch:
        fe = params["frontend"]
        pe = layers.linear(fe["proj2"],
                           jax.nn.gelu(layers.linear(fe["proj1"],
                                                     batch["prefix_embeds"],
                                                     cdt)), cdt)
        x = jnp.concatenate([pe, x], axis=1)
        pm = jnp.zeros((B, pe.shape[1]), bool)
        tm = loss_mask if loss_mask is not None else jnp.ones((B, S_tok), bool)
        loss_mask = jnp.concatenate([pm, tm], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos == "sinusoidal":
        x = x + layers.sinusoidal_positions(positions, cfg.d_model).astype(cdt)
    x = shard(x, ("sub_batch", "seq", "embed"))
    return x, positions, loss_mask


def apply_stack(params, x, cfg: ModelConfig, *, positions, caches=None,
                decode=False, step=None, ring=False, attn_impl="xla"):
    """Run head + scanned body + tail.  Returns (x, caches, aux_losses)."""
    head, n_periods, period_kinds, body_start, tail = stack_layout(cfg)
    total_losses: dict[str, jnp.ndarray] = {}
    new_caches = {"head": [], "tail": [], "body": None}

    def acc_losses(losses):
        for k_, v_ in losses.items():
            total_losses[k_] = total_losses.get(k_, 0.0) + v_

    # Training path: rematerialize each block in the backward pass so the
    # stash per layer is only the residual stream (production default —
    # without this the 4k training activations of the large archs exceed
    # HBM; quantified in EXPERIMENTS.md §Perf).
    use_remat = cfg.remat and caches is None

    def run_block(p, h, kind, is_moe):
        def fn(p_, h_):
            y, _, ls = block_apply(p_, h_, cfg, kind, positions=positions,
                                   is_moe=is_moe, cache=None, decode=False,
                                   step=step, ring=ring, attn_impl=attn_impl)
            return y, ls
        if use_remat:
            fn = jax.checkpoint(fn)
        return fn(p, h)

    for j, (i, kind) in enumerate(head):
        if caches is None:
            x, ls = run_block(params["head"][j], x, kind,
                              cfg.is_moe_layer(i))
            nc = None
        else:
            x, nc, ls = block_apply(params["head"][j], x, cfg, kind,
                                    positions=positions,
                                    is_moe=cfg.is_moe_layer(i),
                                    cache=caches["head"][j], decode=decode,
                                    step=step, ring=ring, attn_impl=attn_impl)
        new_caches["head"].append(nc)
        acc_losses(ls)

    if n_periods > 0:
        is_moe_body = cfg.moe is not None

        def body_fn(carry, xs):
            h = carry
            if caches is not None:
                p_period, c_period = xs
            else:
                p_period, c_period = xs, [None] * len(period_kinds)
            nc_list = []
            ls_acc = None
            for j, kind in enumerate(period_kinds):
                is_moe = is_moe_body and kind == "attn"
                if caches is None:
                    h, ls = run_block(p_period[j], h, kind, is_moe)
                    nc = None
                else:
                    h, nc, ls = block_apply(
                        p_period[j], h, cfg, kind, positions=positions,
                        is_moe=is_moe, cache=c_period[j], decode=decode,
                        step=step, ring=ring, attn_impl=attn_impl)
                nc_list.append(nc)
                vals = [ls.get("moe_aux", jnp.zeros((), jnp.float32)),
                        ls.get("moe_z", jnp.zeros((), jnp.float32))]
                ls_acc = vals if ls_acc is None else [a + b for a, b
                                                      in zip(ls_acc, vals)]
            return h, (nc_list if caches is not None else None,
                       jnp.stack(ls_acc))

        xs = (params["body"], caches["body"]) if caches is not None \
            else params["body"]
        if cfg.scan_layers:
            x, (body_caches, ls_stack) = jax.lax.scan(body_fn, x, xs)
            ls_sum = jnp.sum(ls_stack, axis=0)
        else:
            # unrolled (dry-run roofline mode): identical math, O(L) HLO
            ys = []
            for i in range(n_periods):
                xi = jax.tree.map(lambda t, i=i: t[i], xs)
                x, y = body_fn(x, xi)
                ys.append(y)
            body_caches = (jax.tree.map(lambda *ts: jnp.stack(ts),
                                        *[y[0] for y in ys])
                           if caches is not None else None)
            ls_sum = sum(y[1] for y in ys)
        new_caches["body"] = body_caches
        acc_losses({"moe_aux": ls_sum[0], "moe_z": ls_sum[1]})

    for j, (i, kind) in enumerate(tail):
        if caches is None:
            x, ls = run_block(params["tail"][j], x, kind,
                              cfg.is_moe_layer(i))
            nc = None
        else:
            x, nc, ls = block_apply(params["tail"][j], x, cfg, kind,
                                    positions=positions,
                                    is_moe=cfg.is_moe_layer(i),
                                    cache=caches["tail"][j], decode=decode,
                                    step=step, ring=ring,
                                    attn_impl=attn_impl)
        new_caches["tail"].append(nc)
        acc_losses(ls)

    return x, (new_caches if caches is not None else None), total_losses


def _logits(params, x, cfg: ModelConfig):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(table, x, jnp.dtype(cfg.compute_dtype))
    logits = layers.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return shard(logits, ("sub_batch", "seq", "vocab"))


def forward(params, batch, cfg: ModelConfig, *, attn_impl="xla"):
    """Training/eval forward.  Returns (loss, metrics)."""
    x, positions, loss_mask = _embed_inputs(params, batch, cfg)
    x, _, aux = apply_stack(params, x, cfg, positions=positions,
                            attn_impl=attn_impl)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    logits = _logits(params, x, cfg)

    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:          # frontend prefix present
        prefix = logits.shape[1] - labels.shape[1]
        pad_lab = jnp.zeros((labels.shape[0], prefix), labels.dtype)
        labels = jnp.concatenate([pad_lab, labels], axis=1)
    if loss_mask is None:
        loss_mask = jnp.ones(labels.shape, bool)

    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(loss_mask), 1)
    loss = jnp.sum(nll * loss_mask) / denom
    total = loss + sum(aux.values()) if aux else loss
    metrics = {"loss": loss, **aux,
               "ppl_proxy": jnp.exp(jnp.clip(loss, 0, 20.0))}
    return total, metrics


def decode_step(params, token, caches, step, cfg: ModelConfig, *,
                max_len: int):
    """One-token serve step.  token: (B, 1) -> (logits (B,1,V), caches)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = layers.embed(params["embed"], token, cdt)
    B = token.shape[0]
    positions = jnp.broadcast_to(step[None, None], (B, 1))
    if cfg.pos == "sinusoidal":
        x = x + layers.sinusoidal_positions(positions, cfg.d_model).astype(cdt)
    ring = attention.cache_is_ring(cfg, max_len)
    x, caches, _ = apply_stack(params, x, cfg, positions=positions,
                               caches=caches, decode=True, step=step,
                               ring=ring)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return _logits(params, x, cfg), caches


def prefill(params, batch, cfg: ModelConfig, *, attn_impl="xla"):
    """Full-sequence forward returning logits (inference prefill path)."""
    x, positions, _ = _embed_inputs(params, batch, cfg)
    x, _, _ = apply_stack(params, x, cfg, positions=positions,
                          attn_impl=attn_impl)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return _logits(params, x, cfg)


# ---------------------------------------------------------------------------
# parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _count_cache(cfg: ModelConfig):
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    total = 0
    routed = 0
    embed = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        keys = "/".join(str(p) for p in path)
        if "'ffn'" in keys and ("w_up" in keys or "w_gate" in keys
                                or "w_down" in keys) and "shared" not in keys:
            routed += n
        if "'embed'" in keys or "'unembed'" in keys:
            embed += n
    return total, routed, embed


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    total, routed, _ = _count_cache(cfg)
    if active_only and cfg.moe is not None:
        total = total - routed + routed * cfg.moe.top_k // cfg.moe.num_experts
    return total


def count_embedding_params(cfg: ModelConfig) -> int:
    return _count_cache(cfg)[2]
