"""Optimizers + schedules (built natively; the paper trains with SGD)."""

from repro.optim.optimizers import (Optimizer, adamw, apply_updates,
                                    init_opt_state, sgd)
from repro.optim.schedules import constant, cosine, step_decay, warmup_cosine

__all__ = ["sgd", "adamw", "Optimizer", "init_opt_state", "apply_updates",
           "step_decay", "cosine", "constant", "warmup_cosine"]
