"""Optimizers + schedules (built natively; the paper trains with SGD)."""

from repro.optim.optimizers import (sgd, adamw, Optimizer, init_opt_state,
                                    apply_updates)
from repro.optim.schedules import step_decay, cosine, constant, warmup_cosine

__all__ = ["sgd", "adamw", "Optimizer", "init_opt_state", "apply_updates",
           "step_decay", "cosine", "constant", "warmup_cosine"]
