"""SGD(+momentum) and AdamW as pure pytree transforms.

The paper's experiments use SGD with a x0.2-every-10-epochs decay; AdamW is
provided for the larger assigned architectures.  State lives in a plain
dict so checkpointing and ZeRO-style sharding (dist/train_step.py,
``zero1=True``) treat it like any other pytree.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable          # (grads, state, params, lr) -> (updates, state)


def _tree_zeros(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def sgd(momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": _tree_zeros(params)} if momentum else {}

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                              state["mu"], grads)
            if nesterov:
                upd = jax.tree.map(
                    lambda m, g: momentum * m + g.astype(jnp.float32),
                    mu, grads)
            else:
                upd = mu
            state = {"mu": mu}
        else:
            upd = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        updates = jax.tree.map(lambda u: -lr * u, upd)
        return updates, state

    return Optimizer("sgd", init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"mu": _tree_zeros(params), "nu": _tree_zeros(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v, p: -lr * (m / c1 / (jnp.sqrt(v / c2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer("adamw", init, update)


def init_opt_state(opt: Optimizer, params):
    return opt.init(params)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)
