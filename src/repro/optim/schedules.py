"""Learning-rate schedules (pure functions step -> lr)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr: float, *, decay: float = 0.2, every: int = 10_000):
    """The paper's schedule: multiply by ``decay`` every ``every`` steps
    (they use x0.2 every 10 epochs)."""
    def f(step):
        k = jnp.floor_divide(step, every).astype(jnp.float32)
        return lr * decay ** k
    return f


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return lr * (final_frac + (1 - final_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return f


def warmup_cosine(lr: float, total_steps: int, warmup: int = 100,
                  final_frac: float = 0.1):
    base = cosine(lr, total_steps, final_frac)
    def f(step):
        w = jnp.clip(step.astype(jnp.float32) / max(warmup, 1), 0.0, 1.0)
        return w * base(jnp.maximum(step - warmup, 0))
    return f
