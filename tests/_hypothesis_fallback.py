"""Deterministic fallback for the tiny slice of `hypothesis` the property
tests use (``given`` / ``settings`` / ``strategies.integers`` /
``strategies.tuples``).

Real hypothesis is the declared test dependency (requirements-test.txt) and
is what CI installs; this shim only exists so the property suite still
*runs* — with seeded, reproducible example generation instead of shrinking
search — in hermetic environments where installing it isn't possible.
Import pattern:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st
"""

from __future__ import annotations

import zlib
from types import SimpleNamespace

import numpy as np

__all__ = ["given", "settings", "strategies"]


class _Integers:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def example(self, rng: np.random.Generator):
        return int(rng.integers(self.lo, self.hi + 1))


class _Tuples:
    def __init__(self, *parts):
        self.parts = parts

    def example(self, rng: np.random.Generator):
        return tuple(p.example(rng) for p in self.parts)


strategies = SimpleNamespace(
    integers=lambda lo, hi: _Integers(lo, hi),
    tuples=lambda *parts: _Tuples(*parts),
)


def settings(*, max_examples: int = 20, deadline=None, **_):
    """Record max_examples on the test fn for ``given`` to consume."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    """Run the test once per generated example (seeded by test name).

    Supports the repo's usage shape only: bound test methods
    ``def test_x(self, case)`` decorated ``@given(CASE)`` over
    ``@settings(...)``.  The wrapper deliberately exposes a ``(self)``-only
    signature so pytest does not mistake strategy arguments for fixtures.
    """

    def deco(fn):
        max_examples = getattr(fn, "_fallback_max_examples", 20)

        def wrapper(self):
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for _ in range(max_examples):
                fn(self, *(s.example(rng) for s in strats))

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
