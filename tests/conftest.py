"""Shared test fixtures.

NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here — smoke
tests and benchmarks must see the real single CPU device.  Only
``repro.launch.dryrun`` (run as its own process) forces 512 host devices.
Distributed tests that need a few devices spawn subprocesses or use
``jax.sharding`` on whatever is available.
"""

from __future__ import annotations

import numpy as np
import pytest


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_caches():
    """Release compiled executables between test modules.

    The tier-1 suite compiles hundreds of XLA programs in one process;
    on XLA:CPU the accumulated jit state eventually segfaults the
    compiler partway through the run.  Nothing shares compiled functions
    across module boundaries, so dropping the caches at each module
    teardown keeps the native footprint bounded.  Per-test compile-count
    assertions (``cache_size``) are intra-module and unaffected.
    """
    yield
    import sys

    import jax

    jax.clear_caches()
    # jax.clear_caches() drops jit executables but not the Pallas
    # lowering/interpreter memo tables (module-level lru_caches inside
    # jax._src.pallas.*).  The kernel-sweep modules added in the K-rule
    # PR trace hundreds of pallas_calls; clear those too so the
    # accumulated XLA:CPU state stays bounded.
    for mod_name, mod in list(sys.modules.items()):
        if not mod_name.startswith("jax._src.pallas"):
            continue
        for attr_name in dir(mod):
            attr = getattr(mod, attr_name, None)
            if callable(getattr(attr, "cache_clear", None)):
                attr.cache_clear()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_gradient_matrix(rng, n=400, p=15, f=3, *, byz_scale=20.0,
                         noise=0.3, dtype=np.float32):
    """Worker-major (p, n) gradients: f Byzantine (uniform random), rest =
    shared signal + per-worker minibatch-style noise."""
    mu = rng.normal(size=n)
    mu /= np.linalg.norm(mu)
    honest = mu[None, :] + noise * rng.normal(size=(p - f, n))
    byz = rng.uniform(-byz_scale, byz_scale, size=(f, n))
    return np.concatenate([byz, honest], axis=0).astype(dtype)


@pytest.fixture
def grad_matrix(rng):
    return make_gradient_matrix(rng)
