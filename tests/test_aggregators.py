"""Baseline aggregator unit tests + hypothesis property tests."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis optional in minimal envs
    HAVE_HYPOTHESIS = False

from repro.core import aggregators
from tests.conftest import make_gradient_matrix

ROBUST = ["median", "trimmed_mean", "meamed", "phocas", "krum",
          "multi_krum", "bulyan", "geomed", "flag"]
ALL = ["mean", "pca"] + ROBUST


class TestShapes:
    @pytest.mark.parametrize("name", ALL)
    def test_output_shape_and_finite(self, rng, name):
        Gw = jnp.asarray(make_gradient_matrix(rng, n=100, p=9, f=2))
        d = aggregators.get_aggregator(name)(Gw, f=2)
        assert d.shape == (100,)
        assert bool(jnp.all(jnp.isfinite(d)))


class TestExactSmallCases:
    def test_median_odd(self):
        Gw = jnp.asarray([[1.0, 5.0], [2.0, -1.0], [100.0, 0.0]])
        np.testing.assert_allclose(aggregators.median(Gw), [2.0, 0.0])

    def test_trimmed_mean_drops_extremes(self):
        Gw = jnp.asarray([[0.0], [1.0], [2.0], [3.0], [100.0]])
        np.testing.assert_allclose(aggregators.trimmed_mean(Gw, f=1), [2.0])

    def test_krum_picks_cluster_member(self, rng):
        Gw = make_gradient_matrix(rng, n=50, p=7, f=1, byz_scale=50.0)
        d = np.asarray(aggregators.krum(jnp.asarray(Gw), f=1))
        dists = np.linalg.norm(Gw - d[None, :], axis=1)
        assert dists.argmin() >= 1  # the selected gradient is an honest one

    def test_meamed_equals_mean_when_identical(self):
        Gw = jnp.ones((6, 4)) * 3.0
        np.testing.assert_allclose(aggregators.meamed(Gw, f=2), jnp.full(4, 3.0))

    def test_bulyan_requires_majority(self, rng):
        # p=15, f=3 satisfies p >= 4f + 3.  Low per-worker noise so the
        # beta=3 coordinate average is statistically tight.
        Gw = jnp.asarray(make_gradient_matrix(rng, p=15, f=3, noise=0.05))
        d = aggregators.bulyan(Gw, f=3)
        hm = jnp.mean(Gw[3:], axis=0)
        rel = float(jnp.linalg.norm(d - hm) / jnp.linalg.norm(hm))
        assert rel < 0.5


class TestRobustnessOrdering:
    @pytest.mark.parametrize("name", ROBUST)
    def test_beats_mean_under_attack(self, rng, name):
        Gw = jnp.asarray(make_gradient_matrix(rng, n=400, p=15, f=3,
                                              byz_scale=20.0))
        hm = jnp.mean(Gw[3:], axis=0)
        d = aggregators.get_aggregator(name)(Gw, f=3)
        rel = float(jnp.linalg.norm(d - hm) / jnp.linalg.norm(hm))
        mean_rel = float(jnp.linalg.norm(aggregators.mean(Gw) - hm)
                         / jnp.linalg.norm(hm))
        assert rel < mean_rel, f"{name}: {rel} !< {mean_rel}"


if HAVE_HYPOTHESIS:
    gw_strategy = st.tuples(
        st.integers(min_value=5, max_value=12),   # p
        st.integers(min_value=8, max_value=64),   # n
        st.integers(min_value=0, max_value=123456),
    )

    class TestProperties:
        @given(gw_strategy)
        @settings(max_examples=20, deadline=None)
        def test_permutation_invariance(self, args):
            """Aggregators must not care about worker order."""
            p, n, seed = args
            r = np.random.default_rng(seed)
            Gw = jnp.asarray(r.normal(size=(p, n)).astype(np.float32))
            perm = r.permutation(p)
            for name in ["mean", "median", "trimmed_mean", "flag", "geomed"]:
                d1 = aggregators.get_aggregator(name)(Gw, f=1)
                d2 = aggregators.get_aggregator(name)(Gw[perm], f=1)
                np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                           rtol=2e-2, atol=2e-3,
                                           err_msg=name)

        @given(gw_strategy)
        @settings(max_examples=20, deadline=None)
        def test_aggregate_in_convex_hull_coordinatewise(self, args):
            """Coordinate-wise rules stay within per-coordinate min/max."""
            p, n, seed = args
            r = np.random.default_rng(seed)
            Gw = jnp.asarray(r.normal(size=(p, n)).astype(np.float32))
            lo, hi = jnp.min(Gw, 0), jnp.max(Gw, 0)
            for name in ["mean", "median", "trimmed_mean", "meamed", "phocas"]:
                d = aggregators.get_aggregator(name)(Gw, f=1)
                assert bool(jnp.all(d >= lo - 1e-5)) and bool(jnp.all(d <= hi + 1e-5)), name

        @given(gw_strategy)
        @settings(max_examples=15, deadline=None)
        def test_scale_equivariance_mean_like(self, args):
            """Scaling all gradients scales the aggregate (homogeneity)."""
            p, n, seed = args
            r = np.random.default_rng(seed)
            Gw = jnp.asarray(r.normal(size=(p, n)).astype(np.float32))
            for name in ["mean", "median", "flag"]:
                d1 = aggregators.get_aggregator(name)(Gw, f=1)
                d2 = aggregators.get_aggregator(name)(3.0 * Gw, f=1)
                np.testing.assert_allclose(np.asarray(3.0 * d1), np.asarray(d2),
                                           rtol=3e-2, atol=3e-3, err_msg=name)
