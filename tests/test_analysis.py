"""repro.analysis: the graph-lint subsystem's own test suite.

One known-bad / known-good fixture pair per rule family (true positive
AND true negative — a rule that cannot fire is worse than no rule), the
``@contract`` decorator semantics (zero-cost off, violation on, tracer
bypass, per-signature caching), the PRECISION lint-regression fixtures
for the ``src/repro/models`` fixes this PR shipped, and the end-to-end
"public entry points are lint-clean" acceptance sweep that
``tools/jaxlint.py`` gates CI with.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.analysis as A
from repro.analysis.entrypoints import run_sweep

BF = jnp.bfloat16


def _bf16_mats(m=4, k=8, n=4):
    rng = np.random.default_rng(0)
    return (jnp.asarray(rng.normal(size=(m, k)), BF),
            jnp.asarray(rng.normal(size=(k, n)), BF))


# ---------------------------------------------------------------------------
# SHAPE
# ---------------------------------------------------------------------------

class TestShapeRule:
    def test_max_dim_true_positive(self):
        g = A.capture(lambda a: a @ a.T, jnp.ones((3, 5)), compile=False)
        findings = A.check_shape(g, max_dim=4)
        assert findings and all(f.rule == "shape" for f in findings)

    def test_max_dim_true_negative(self):
        g = A.capture(lambda a: a @ a.T, jnp.ones((3, 5)), compile=False)
        assert A.check_shape(g, max_dim=5) == []

    def test_forbidden_and_required_on_hlo(self):
        x = jnp.ones((4, 16))
        hlo = jax.jit(lambda a: a.sum(0)).lower(x).compile().as_text()
        g = A.Graph("sum", None, hlo)
        assert A.check_shape(g, forbidden_dims={16}, require_dims={16})
        assert A.check_shape(g, forbidden_dims={999},
                             require_dims={16}) == []

    def test_required_dims_absent_is_a_finding(self):
        """Detector sanity is part of the rule: requiring a dimension that
        never appears means the check is not looking at the right graph."""
        g = A.capture(lambda a: a * 2, jnp.ones((4,)), compile=False)
        findings = A.check_shape(g, require_dims={777})
        assert len(findings) == 1 and "required" in findings[0].message

    def test_full_width_dims_derivation(self):
        tree = {"a": jnp.zeros((8, 1024)), "b": jnp.zeros((8, 256, 2))}
        forbidden, required = A.full_width_dims(tree, 8)
        assert {1024, 512, 256, 1536} <= forbidden
        assert {128, 64, 32} <= required
        assert not (forbidden & required)

    def test_needs_a_graph(self):
        with pytest.raises(ValueError):
            A.check_shape(A.Graph("empty"), max_dim=1)


# ---------------------------------------------------------------------------
# PRECISION
# ---------------------------------------------------------------------------

class TestPrecisionRule:
    def test_bf16_matmul_true_positive(self):
        x, w = _bf16_mats()
        g = A.capture(lambda a, b: a @ b, x, w, compile=False)
        findings = A.check_precision(g)
        assert findings and findings[0].op == "dot_general"

    def test_fp32_accumulated_matmul_true_negative(self):
        x, w = _bf16_mats()

        def fixed(a, b):
            return jnp.matmul(a, b,
                              preferred_element_type=jnp.float32).astype(BF)

        assert A.check_precision(A.capture(fixed, x, w, compile=False)) == []

    def test_bf16_accumulating_ops_true_positive(self):
        # jnp.sum upcasts internally, but cumsum and scatter-add keep the
        # operand dtype — both are bf16 accumulators the rule must flag
        x, _ = _bf16_mats()
        g = A.capture(lambda a: jnp.cumsum(a, axis=0), x, compile=False)
        assert A.check_precision(g)
        idx = jnp.asarray([0, 1, 0, 1])
        g2 = A.capture(lambda a: jnp.zeros((2, 8), BF).at[idx].add(a),
                       x, compile=False)
        assert A.check_precision(g2)

    def test_default_jnp_sum_true_negative(self):
        """jnp.sum's built-in fp32 accumulation must not be flagged."""
        x, _ = _bf16_mats()
        g = A.capture(lambda a: a.sum(0), x, compile=False)
        assert A.check_precision(g) == []

    def test_fp32_graph_never_flags(self):
        g = A.capture(lambda a: (a @ a.T).sum(), jnp.ones((6, 6)),
                      compile=False)
        assert A.check_precision(g) == []

    def test_sees_through_jit_and_scan(self):
        x, w = _bf16_mats()

        @jax.jit
        def scanned(a, b):
            def body(c, _):
                return c @ b, ()
            out, _ = jax.lax.scan(body, a, None, length=3)
            return out

        g = A.capture(scanned, x, jnp.asarray(np.eye(8), BF), compile=False)
        assert A.check_precision(g)


# ---------------------------------------------------------------------------
# TRANSFER
# ---------------------------------------------------------------------------

class TestTransferRule:
    def test_pure_callback_true_positive(self):
        def with_cb(x):
            return jax.pure_callback(
                lambda v: np.asarray(v) * 2,
                jax.ShapeDtypeStruct(x.shape, x.dtype), x)

        g = A.capture(with_cb, jnp.ones((4,)), compile=False)
        findings = A.check_transfer(g)
        assert findings and "callback" in findings[0].op

    def test_clean_graph_true_negative(self):
        g = A.capture(lambda x: jnp.sin(x).sum(), jnp.ones((4,)),
                      compile=False)
        assert A.check_transfer(g) == []

    def test_literal_device_put_is_not_a_transfer(self):
        """Regression: jnp wraps Python scalars in device_put[devices=
        [None]] — the q-space solver tripped this before the rule learned
        to ignore the no-op form."""
        g = A.capture(lambda x: x * 2 + 1, jnp.ones((4,)), compile=False)
        assert A.check_transfer(g) == []


# ---------------------------------------------------------------------------
# MASK
# ---------------------------------------------------------------------------

class TestMaskRule:
    MASK = jnp.asarray([1.0, 0.0, 1.0, 1.0])

    def test_traced_consumption_true_negative(self):
        base = jnp.arange(4.0)
        assert A.check_mask(lambda m: (m * base).sum(), self.MASK) == []

    def test_python_branch_true_positive(self):
        def branchy(m):
            if m[0] > 0:                     # concretizes the tracer
                return jnp.zeros(())
            return jnp.ones(())

        findings = A.check_mask(branchy, self.MASK, name="branchy")
        assert findings and findings[0].op == "python-branch"

    def test_ignored_mask_true_positive(self):
        findings = A.check_mask(lambda m: jnp.arange(4.0).sum(), self.MASK,
                                name="ignoring")
        assert findings and findings[0].op == "<unused>"


# ---------------------------------------------------------------------------
# COLLECTIVES
# ---------------------------------------------------------------------------

_AR_HLO = """
ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024] parameter(0)
  ROOT %ar = f32[1024] all-reduce(%p0), replica_groups=[1,8]<=[8], to_apply=%add
}
"""


class TestCollectivesRule:
    # ring all-reduce of 4096 B over 8 devices: 4096 * 2 * 7/8 = 7168 B
    def test_over_budget_true_positive(self):
        g = A.Graph("ar", None, _AR_HLO)
        findings = A.check_collectives(g, 8, max_bytes_per_device=1000.0)
        assert findings and "7.168e+03" in findings[0].message

    def test_within_budget_true_negative(self):
        g = A.Graph("ar", None, _AR_HLO)
        assert A.check_collectives(g, 8, max_bytes_per_device=1e6) == []

    def test_requires_hlo(self):
        g = A.capture(lambda x: x, jnp.ones(()), compile=False)
        with pytest.raises(ValueError):
            A.check_collectives(g, 8, max_bytes_per_device=1.0)


# ---------------------------------------------------------------------------
# RECOMPILE
# ---------------------------------------------------------------------------

class TestRecompileRule:
    def test_shape_polymorphic_drive_true_positive(self):
        f = jax.jit(lambda x: x.sum())
        variants = [(jnp.ones((n,)),) for n in (3, 4, 5)]
        findings = A.check_recompile(f, variants, name="shapeful")
        assert findings and "compiled 3x" in findings[0].message

    def test_value_variants_true_negative(self):
        f = jax.jit(lambda x: x * 2)
        variants = [(jnp.full((4,), float(i)),) for i in range(5)]
        assert A.check_recompile(f, variants) == []

    def test_assert_raises_contract_violation(self):
        f = jax.jit(lambda x: x.sum())
        with pytest.raises(A.ContractViolation):
            A.assert_no_recompile(f, [(jnp.ones((n,)),) for n in (2, 3)])

    def test_cache_size_rejects_plain_functions(self):
        with pytest.raises(TypeError):
            A.cache_size(lambda x: x)


# ---------------------------------------------------------------------------
# @contract
# ---------------------------------------------------------------------------

class TestContractDecorator:
    def _bad(self):
        calls = {"n": 0}

        @A.contract(fp32_contractions=True)
        def entry(a, b):
            calls["n"] += 1
            return a @ b

        return entry, calls

    def test_zero_cost_when_disabled(self):
        entry, calls = self._bad()
        x, w = _bf16_mats()
        entry(x, w)                      # no checking machinery ran
        assert calls["n"] == 1

    def test_violation_when_enabled(self):
        entry, _ = self._bad()
        x, w = _bf16_mats()
        with A.checking():
            with pytest.raises(A.ContractViolation) as ei:
                entry(x, w)
        assert ei.value.findings[0].rule == "precision"
        # ContractViolation is an AssertionError so plain asserts and the
        # contract checks fail tests through one exception family
        assert isinstance(ei.value, AssertionError)

    def test_signature_cache_traces_once(self):
        calls = {"n": 0}

        @A.contract(fp32_contractions=True)
        def entry(a):
            calls["n"] += 1
            return (a @ a.T).sum()

        x = jnp.ones((3, 3))
        with A.checking():
            entry(x)                     # trace (1) + call (1)
            entry(x)                     # cached signature: call only
            assert calls["n"] == 3
            entry(jnp.ones((4, 4)))      # new signature: trace + call
            assert calls["n"] == 5

    def test_tracer_args_bypass(self):
        entry, calls = self._bad()
        x, w = _bf16_mats()
        with A.checking():
            jax.jit(lambda a, b: entry(a, b))(x, w)  # no violation: the
        assert calls["n"] == 1                       # enclosing jit owns it

    def test_callable_max_dim_waiver(self):
        @A.contract(max_dim=lambda a, *r, **kw: (
            None if kw.get("oracle") else a.shape[0]))
        def entry(a, *, oracle=False):
            big = jnp.zeros((a.shape[0] * 3,))
            return a.sum() + big.sum()

        x = jnp.ones((4,))
        with A.checking():
            entry(x, oracle=True)        # waived
            with pytest.raises(A.ContractViolation):
                entry(x)

    def test_enable_disable_scoping(self):
        assert not A.contracts_enabled()
        with A.checking():
            assert A.contracts_enabled()
        assert not A.contracts_enabled()

    def test_metadata_and_wrapped(self):
        entry, _ = self._bad()
        assert entry.__contract__["fp32_contractions"] is True
        assert callable(entry.__wrapped__)


# ---------------------------------------------------------------------------
# PRECISION lint-regression fixtures for the src/repro/models fixes
# ---------------------------------------------------------------------------

class TestModelPrecisionFixtures:
    """Each graph here was flagged by the PRECISION rule before this PR
    fixed it (fp32 accumulation on every bf16 contraction); these pin the
    fixes.  The bf16 serve/prefill/decode entry points are swept
    end-to-end by tools/jaxlint.py and TestEntryPointSweep."""

    def _clean(self, fn, *args, **kwargs):
        g = A.capture(fn, *args, compile=False, **kwargs)
        findings = A.check_precision(g)
        assert findings == [], "\n".join(f.render() for f in findings)

    def test_linear_bf16(self):
        from repro.models import layers
        p = layers.linear_init(jax.random.PRNGKey(0), 16, 8)
        x = jnp.ones((2, 16), BF)
        self._clean(layers.linear, p, x)

    def test_unembed_bf16(self):
        from repro.models import layers
        p = {"table": jnp.ones((32, 16))}
        self._clean(layers.unembed, p, jnp.ones((2, 16), BF))

    def test_moe_bf16(self):
        from repro.models import moe as moe_lib
        from repro.models.config import ModelConfig, MoESettings
        cfg = ModelConfig(
            name="t", arch_type="moe", num_layers=2, d_model=32, num_heads=4,
            num_kv_heads=4, d_ff=64, vocab_size=64,
            moe=MoESettings(num_experts=4, top_k=2, num_shared=2,
                            d_expert=64, capacity_factor=4.0),
            compute_dtype="bfloat16")
        p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jnp.ones((2, 8, 32), BF)
        self._clean(lambda: moe_lib.moe_apply(p, x, cfg))

    def test_blockdiag_bf16(self):
        from repro.models import ssm
        p = ssm._blockdiag_init(jax.random.PRNGKey(0), 32, 8, jnp.float32)
        self._clean(ssm._blockdiag_apply, p, jnp.ones((2, 32), BF), BF)

    def test_codec_paths_bf16(self):
        from repro.comm import CommConfig, init_ef
        from repro.core import FlagConfig
        from repro.dist.aggregation import (AggregatorConfig,
                                            compressed_aggregate)
        rng = np.random.default_rng(3)
        tree = {"a": jnp.asarray(rng.normal(size=(4, 64)), BF)}
        cfg = AggregatorConfig("flag", f=1, flag=FlagConfig(lam=2.0, m=2,
                                                            tol=0.0))
        cs = CommConfig(codec="countsketch", sketch_ratio=0.25)
        self._clean(lambda: compressed_aggregate(tree, cfg, cs))
        sg = CommConfig(codec="signsgd")
        ef = init_ef({"a": jnp.zeros((64,), BF)}, 4)
        self._clean(lambda: compressed_aggregate(tree, cfg, sg, ef))


# ---------------------------------------------------------------------------
# kernel rules (KTILING / KRACE / KVMEM / KPRECISION / KSENTINEL):
# deliberately-broken mutant kernels as true-positive fixtures
# ---------------------------------------------------------------------------

def _mutant_jaxpr(kernel, *, grid, in_specs, out_specs, out_shape,
                  in_shapes=((32, 128),), in_dtype=jnp.float32,
                  **pallas_kwargs):
    """Trace (never run) a pallas_call mutant into a lintable jaxpr."""
    from jax.experimental import pallas as pl

    args = [jnp.zeros(s, in_dtype) for s in in_shapes]
    fn = pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                        out_specs=out_specs, out_shape=out_shape,
                        interpret=True, **pallas_kwargs)
    return jax.make_jaxpr(fn)(*args)


def _only_finding(jaxpr, rule: str, op: str, **kwargs):
    """Assert the full K-rule battery fires *exactly* the expected
    finding (and nothing else) — mutants must be surgical."""
    from repro.analysis.pallas_rules import check_kernels

    findings = check_kernels(jaxpr, **kwargs)
    assert len(findings) == 1, "\n".join(f.render() for f in findings)
    assert findings[0].rule == rule and findings[0].op == op, \
        findings[0].render()
    return findings[0]


class TestKernelMutants:
    def test_race_unconditional_overwrite(self):
        """A revisited output block clobbered by a value independent of
        the ref: later grid steps erase earlier ones."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0     # no accumulate, no guard

        jx = _mutant_jaxpr(
            kernel, grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32))
        f = _only_finding(jx, "krace", "unguarded-overwrite")
        assert "revisits" in f.message

    def test_oob_tile(self):
        """Index map walks past the padded operand."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i + 1, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
            in_shapes=((16, 128),))
        f = _only_finding(jx, "ktiling", "oob-block")
        assert "overruns" in f.message

    def test_overlapping_tiles(self):
        """Two distinct grid steps write the same output block along a
        *dependent* axis — overlap, not accumulation."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i // 2, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32))
        f = _only_finding(jx, "ktiling", "overlapping-tiles")
        assert "2 distinct grid indices" in f.message

    def test_uncovered_output_block(self):
        """Grid never visits part of the output: uninitialized memory."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
            in_shapes=((32, 128),))
        _only_finding(jx, "ktiling", "uncovered-block")

    def test_bf16_accumulator(self):
        """A correctly-guarded accumulator that is bf16: every store
        rounds the running sum."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            i = pl.program_id(0)

            @pl.when(i == 0)
            def _init():
                o_ref[...] = jnp.zeros_like(o_ref)

            o_ref[...] += x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(4,),
            in_specs=[pl.BlockSpec((16, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((16, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 128), jnp.bfloat16),
            in_shapes=((64, 128),), in_dtype=jnp.bfloat16)
        f = _only_finding(jx, "kprecision", "low-precision-accumulator")
        assert "bfloat16" in f.message

    def test_infinite_sentinel(self):
        """Masking with -inf instead of a finite sentinel."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            x = x_ref[...]
            rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
            o_ref[...] = jnp.where(rows < 4, x, -jnp.inf)

        jx = _mutant_jaxpr(
            kernel, grid=(1,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            in_shapes=((8, 128),))
        f = _only_finding(jx, "ksentinel", "nonfinite-sentinel")
        assert "-inf" in f.message

    def test_vmem_blowout(self):
        """Per-grid-step working set (double-buffered) over the budget."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec((1024, 2048), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1024, 2048), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((2048, 2048), jnp.float32),
            in_shapes=((2048, 2048),))
        f = _only_finding(jx, "kvmem", "working-set")
        assert "exceeds the budget" in f.message
        # the same site passes with a budget that actually fits it
        from repro.analysis.pallas_rules import check_kernel_vmem
        assert check_kernel_vmem(jx, max_bytes=64 * 2**20) == []

    def test_misaligned_block(self):
        """A lane-dim block width that is neither 128-aligned nor the
        full array dim silently inflates every tile."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec((8, 100), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 100), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 200), jnp.float32),
            in_shapes=((16, 200),))
        from repro.analysis.pallas_rules import check_kernel_vmem
        findings = check_kernel_vmem(jx)
        assert findings and all(f.op == "misaligned-block"
                                for f in findings)

    def test_input_write_without_alias(self):
        """Writing an input ref with no declared input_output_alias."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]
            x_ref[...] = o_ref[...] * 0.0

        jx = _mutant_jaxpr(
            kernel, grid=(1,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            in_shapes=((8, 128),))
        f = _only_finding(jx, "krace", "input-write")
        assert "input_output_alias" in f.message

    def test_missing_guarded_init(self):
        """Reading a revisited accumulator with no first-visit init: the
        first grid step consumes uninitialized VMEM."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] += x_ref[...]          # accumulate, but never init

        jx = _mutant_jaxpr(
            kernel, grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32))
        f = _only_finding(jx, "krace", "missing-init")
        assert "uninitialized" in f.message

    def test_unread_mask_operand(self):
        """A membership mask that is accepted but never consumed."""
        from jax.experimental import pallas as pl

        def kernel(x_ref, m_ref, o_ref):
            o_ref[...] = x_ref[...]           # m_ref ignored

        jx = _mutant_jaxpr(
            kernel, grid=(1,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0)),
                      pl.BlockSpec((8, 1), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            in_shapes=((8, 128), (8, 1)))
        f = _only_finding(jx, "ksentinel", "mask-unread",
                          mask_inputs=(1,))
        assert "never read" in f.message


class TestKernelRuleNegatives:
    """The guarded-accumulation idiom and friends must lint clean."""

    def test_guarded_accumulator_is_clean(self):
        from jax.experimental import pallas as pl
        from repro.analysis.pallas_rules import check_kernels

        def kernel(x_ref, o_ref):
            i = pl.program_id(0)

            @pl.when(i == 0)
            def _init():
                o_ref[...] = jnp.zeros_like(o_ref)

            o_ref[...] += x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32))
        assert check_kernels(jx, expect_sites=1) == []

    def test_site_count_mismatch_is_a_finding(self):
        """Detector sanity: promising N kernels over a kernel-free graph
        must fail, not vacuously pass."""
        from repro.analysis.pallas_rules import check_kernels

        jx = jax.make_jaxpr(lambda a: a @ a.T)(jnp.ones((4, 8)))
        findings = check_kernels(jx, expect_sites=1, name="phantom")
        assert len(findings) == 1 and findings[0].op == "<site-count>"

    def test_extraction_recovers_structure(self):
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        jx = _mutant_jaxpr(
            kernel, grid=(2, 3),
            in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((16, 384), jnp.float32),
            in_shapes=((16, 384),))
        (site,) = A.find_pallas_calls(jx)
        assert site.grid == (2, 3)
        (out,) = site.outputs
        assert out.block_shape == (8, 128)
        assert out.array_shape == (16, 384)
        assert site.revisit_axes(out) == set()
        assert site.dependent_axes(out) == {0, 1}
        assert len(site.visits(out)) == 6

    def test_contract_kernel_options(self):
        """kernel_race/kernel_budget on @contract fire through the
        decorator (and stay silent on a clean graph)."""
        from jax.experimental import pallas as pl

        def bad_kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        def make_entry(**copts):
            @A.contract(**copts)
            def entry(x):
                return pl.pallas_call(
                    bad_kernel, grid=(4,),
                    in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
                    out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
                    interpret=True)(x)
            return entry

        x = jnp.zeros((32, 128), jnp.float32)
        with A.checking():
            with pytest.raises(A.ContractViolation) as exc:
                make_entry(kernel_race=True)(x)
            assert any(f.rule == "krace" for f in exc.value.findings)
            # budget-only contract: the race is out of scope, and the
            # working set fits — no violation
            make_entry(kernel_budget=True)(x)


# ---------------------------------------------------------------------------
# end-to-end: the public entry points are lint-clean
# ---------------------------------------------------------------------------

class TestEntryPointSweep:
    def test_fast_subset_is_lint_clean(self):
        """Tier-1 acceptance: the aggregation-layer entry points (all the
        cheap-to-trace ones) produce zero findings."""
        report = run_sweep(
            sharded="skip",
            names=["gram_solver", "aggregate_tree/flag",
                   "aggregate_tree/median", "aggregate_tree/krum",
                   "compressed_aggregate", "recompile/membership_at",
                   "recompile/fa_weights_masked"])
        assert report.clean, "\n" + report.render()

    def test_kernel_entries_are_lint_clean(self):
        """Tier-1 acceptance for the K-rules: every production
        pallas_call site sweeps clean (trace-only — nothing executes)."""
        report = run_sweep(sharded="skip", names=["kernels/"])
        assert len(report.sections) >= 12
        assert report.clean, "\n" + report.render()

    @pytest.mark.slow
    def test_full_sweep_is_lint_clean(self):
        """The whole tools/jaxlint.py surface (CI runs this via the
        gating lint-contracts lane; here it rides the slow lane too)."""
        report = run_sweep(sharded="auto")
        assert report.clean, "\n" + report.render()
