"""Attack library tests."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks


@pytest.fixture
def honest(rng):
    return jnp.asarray(rng.normal(size=(10, 64)).astype(np.float32))


KEY = jax.random.PRNGKey(0)


class TestAttacks:
    def test_none_is_identity(self, honest):
        out = attacks.apply_attack("none", honest, KEY, f=3)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(honest))

    def test_f_zero_is_identity(self, honest):
        for name in attacks.ATTACKS:
            out = attacks.apply_attack(name, honest, KEY, f=0)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(honest),
                                          err_msg=name)

    def test_honest_rows_untouched(self, honest):
        for name in attacks.ATTACKS:
            out = attacks.apply_attack(name, honest, KEY, f=4)
            np.testing.assert_array_equal(np.asarray(out[4:]),
                                          np.asarray(honest[4:]), err_msg=name)

    def test_sign_flip(self, honest):
        out = attacks.apply_attack("sign_flip", honest, KEY, f=2, scale=10.0)
        np.testing.assert_allclose(np.asarray(out[:2]),
                                   -10.0 * np.asarray(honest[:2]), rtol=1e-6)

    def test_zero(self, honest):
        out = attacks.apply_attack("zero", honest, KEY, f=2)
        assert float(jnp.abs(out[:2]).max()) == 0.0

    def test_ipm_direction(self, honest):
        out = attacks.apply_attack("ipm", honest, KEY, f=2, eps=0.1)
        mu = jnp.mean(honest[2:], axis=0)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(-0.1 * mu),
                                   rtol=1e-5, atol=1e-6)

    def test_drop_rate(self, honest):
        out = attacks.apply_attack("drop", honest, KEY, f=10, loss_rate=0.5)
        frac = float(jnp.mean(out == 0.0))
        assert 0.3 < frac < 0.7

    def test_alie_within_band(self, honest):
        out = attacks.apply_attack("alie", honest, KEY, f=2, z=1.5)
        mu = np.asarray(jnp.mean(honest[2:], axis=0))
        sd = np.asarray(jnp.std(honest[2:], axis=0))
        np.testing.assert_allclose(np.asarray(out[0]), mu - 1.5 * sd,
                                   rtol=1e-4, atol=1e-5)

    def test_deterministic(self, honest):
        a = attacks.apply_attack("random", honest, KEY, f=3)
        b = attacks.apply_attack("random", honest, KEY, f=3)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_unknown_raises(self, honest):
        with pytest.raises(KeyError):
            attacks.apply_attack("nope", honest, KEY, f=1)

    def test_jittable(self, honest):
        f = jax.jit(lambda g, k: attacks.ATTACKS["random"](
            g, k, attacks.byzantine_mask(10, 3)))
        out = f(honest, KEY)
        assert out.shape == honest.shape
