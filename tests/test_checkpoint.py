"""Crash-safe checkpoint layer (format v2) + resume-equivalence tests.

Crash recovery: a save is only visible once its commit marker lands, so a
SIGKILL at any point mid-save (simulated by truncating the npz / dropping
meta / dropping the marker) leaves a step dir that ``latest_step`` skips
and resume lands on the previous complete step.

Resume equivalence (the elastic driver's contract): running 2N steps
uninterrupted == running N steps, killing the process, restoring from the
checkpoint and running the remaining N — bit-identical losses (<= 1e-6
with error feedback), across the aggregator x attack x codec acceptance
matrix.  Local rngs throughout (the shared session-scoped fixture makes
statistical tolerances order-dependent).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (checkpoint_meta, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpoint import _commit_name, _state_name, _step_dir
from repro.launch.elastic import (ElasticConfig, build_harness,
                                  verify_elastic)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(7,)), jnp.bfloat16),
        "count": jnp.asarray(rng.integers(0, 100, (2,)), jnp.int32),
    }


class TestCheckpointV2:
    def test_roundtrip_bitwise(self, tmp_path):
        tree = _tree(0)
        save_checkpoint(str(tmp_path), 5, tree, extra={"total_steps": 20})
        out, step = load_checkpoint(str(tmp_path), jax.tree.map(
            jnp.zeros_like, tree))
        assert step == 5
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float32) if a.dtype == jnp.bfloat16 else
                np.asarray(a),
                np.asarray(b, np.float32) if b.dtype == jnp.bfloat16 else
                np.asarray(b))
        assert checkpoint_meta(str(tmp_path))["extra"]["total_steps"] == 20

    def test_save_is_atomic_layout(self, tmp_path):
        d = save_checkpoint(str(tmp_path), 3, _tree())
        names = sorted(os.listdir(d))
        assert names == ["commit_0.json", "meta_0.json", "state_0.npz"]
        assert not [n for n in names if n.endswith(".tmp")]
        commit = json.load(open(os.path.join(d, "commit_0.json")))
        assert commit["state_bytes"] == os.path.getsize(
            os.path.join(d, "state_0.npz"))

    @pytest.mark.parametrize("corruption",
                             ["truncate_npz", "drop_meta", "drop_marker",
                              "drop_npz"])
    def test_latest_step_skips_torn_write(self, tmp_path, corruption):
        """SIGKILL-simulation: whatever part of the newest save is missing
        or torn, resume lands on the previous complete step."""
        tree = _tree(0)
        save_checkpoint(str(tmp_path), 2, tree)
        save_checkpoint(str(tmp_path), 4, _tree(1))
        d4 = _step_dir(str(tmp_path), 4)
        if corruption == "truncate_npz":
            p = os.path.join(d4, _state_name(0))
            with open(p, "rb+") as f:
                f.truncate(os.path.getsize(p) // 2)
        elif corruption == "drop_meta":
            os.unlink(os.path.join(d4, "meta_0.json"))
        elif corruption == "drop_marker":
            os.unlink(os.path.join(d4, _commit_name(0)))
        else:
            os.unlink(os.path.join(d4, _state_name(0)))
        assert latest_step(str(tmp_path)) == 2
        out, step = load_checkpoint(str(tmp_path), jax.tree.map(
            jnp.zeros_like, tree))
        assert step == 2
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))

    def test_empty_and_all_torn(self, tmp_path):
        assert latest_step(str(tmp_path)) is None
        save_checkpoint(str(tmp_path), 1, _tree())
        os.unlink(os.path.join(_step_dir(str(tmp_path), 1), _commit_name(0)))
        assert latest_step(str(tmp_path)) is None
        with pytest.raises(FileNotFoundError):
            load_checkpoint(str(tmp_path), _tree())

    def test_multi_process_meta_not_clobbered(self, tmp_path):
        """Each process namespaces its state AND meta: key manifests stay
        per-writer (v1 clobbered meta.json with whichever landed last)."""
        t0 = {"only_p0": jnp.ones((2,))}
        t1 = {"only_p1": jnp.zeros((3, 3))}
        save_checkpoint(str(tmp_path), 7, t0, process_index=0)
        save_checkpoint(str(tmp_path), 7, t1, process_index=1)
        m0 = checkpoint_meta(str(tmp_path), process_index=0)
        m1 = checkpoint_meta(str(tmp_path), process_index=1)
        assert m0["keys"] != m1["keys"]
        assert any("only_p0" in k for k in m0["keys"])
        assert any("only_p1" in k for k in m1["keys"])
        out0, _ = load_checkpoint(str(tmp_path), jax.tree.map(
            jnp.zeros_like, t0), process_index=0)
        out1, _ = load_checkpoint(str(tmp_path), jax.tree.map(
            jnp.ones_like, t1), process_index=1)
        np.testing.assert_array_equal(np.asarray(out0["only_p0"]),
                                      np.ones((2,)))
        np.testing.assert_array_equal(np.asarray(out1["only_p1"]),
                                      np.zeros((3, 3)))
        # completeness is per process too
        os.unlink(os.path.join(_step_dir(str(tmp_path), 7),
                               _commit_name(1)))
        assert latest_step(str(tmp_path), process_index=0) == 7
        assert latest_step(str(tmp_path), process_index=1) is None

    def test_v1_layout_still_readable(self, tmp_path):
        """Old checkpoints (shared meta.json, no marker) load unchanged."""
        tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
        d = _step_dir(str(tmp_path), 9)
        os.makedirs(d)
        flat = jax.tree_util.tree_flatten_with_path(tree)
        arrays = {jax.tree_util.keystr(p): np.asarray(l)
                  for p, l in flat[0]}
        with open(os.path.join(d, "state_0.npz"), "wb") as f:
            np.savez(f, **arrays)
        with open(os.path.join(d, "meta.json"), "w") as f:
            json.dump({"step": 9, "treedef": str(flat[1]),
                       "bf16": [], "keys": sorted(arrays)}, f)
        assert latest_step(str(tmp_path)) == 9
        out, step = load_checkpoint(str(tmp_path),
                                    jax.tree.map(jnp.zeros_like, tree))
        assert step == 9
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# resume equivalence: the acceptance matrix
# ---------------------------------------------------------------------------

N = 3  # kill-and-resume horizon: 2N total steps, killed mid-flight


@pytest.mark.parametrize("codec", ["identity", "signsgd"])
@pytest.mark.parametrize("attack", ["none", "sign_flip"])
@pytest.mark.parametrize("agg", ["flag", "krum", "mean"])
class TestKillAndResume:
    """(N steps -> checkpoint -> kill -> resume -> N steps) == 2N steps,
    bit-identical losses (<= 1e-6 with EF), for every combination of
    {flag, krum, mean} x {none, sign_flip} x {identity, signSGD}."""

    def test_trajectory_matches_uninterrupted(self, tmp_path, agg, attack,
                                              codec):
        cfg = ElasticConfig(
            steps=2 * N, workers=6, per_worker_batch=2, seq=32,
            aggregator=agg, attack=attack,
            byzantine=1 if attack != "none" else 0,
            codec=codec, ckpt_every=N)
        h = build_harness(cfg)
        out = verify_elastic(h, str(tmp_path / "ckpt"),
                             kill_at=(N + 1,), tol=1e-6)
        assert out["kills"] == [N + 1]
        assert out["replayed"] >= 1              # the kill really replayed
        assert out["ok"], (out["max_diff"], out["replay_mismatch"])


def test_resume_uses_persisted_lr_horizon(tmp_path):
    """The elastic driver stores total_steps in the checkpoint meta; a
    mismatching resume horizon is a detectable bug, not a silent re-warm."""
    cfg = ElasticConfig(steps=2 * N, workers=5, per_worker_batch=2, seq=32,
                        aggregator="mean", ckpt_every=N)
    h = build_harness(cfg)
    from repro.launch.elastic import run_elastic
    run_elastic(h, str(tmp_path / "c"), kill_at=())
    meta = checkpoint_meta(str(tmp_path / "c"))
    assert meta["extra"]["total_steps"] == 2 * N
