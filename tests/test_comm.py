"""Compression-layer tests (`repro.comm` + the dist aggregation bridge).

Covers: codec round-trip/identity properties, EF-corrected mean recovery
(generative, via the hypothesis fallback harness), codec x {flag, krum,
mean} finiteness through the real distributed train step, the >= 8x
comm_bits reduction the acceptance criteria require, EF-compressed
training staying within 5% of the uncompressed final loss under the
lockstep attack config, and — via repro.analysis.hlo on the compiled
step — that the CountSketch codec feeds FA's Gram path without ever
materializing a decoded (W, n) stack.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.comm import (CODECS, CommConfig, dense_bits, ef_encode_decode,
                        get_codec, init_ef, majority_vote)
from repro.core.flag import FlagConfig
from repro.data.synthetic import SyntheticLM
from repro.dist.aggregation import (AggregatorConfig, aggregate_tree,
                                    compressed_aggregate)
from repro.dist.train_step import (TrainConfig, build_train_step,
                                   init_train_state)
from repro.models.config import ModelConfig
from repro.optim import constant, sgd

W, B, S, F = 6, 2, 16, 2

CFG = ModelConfig(name="tiny-comm", arch_type="dense", num_layers=2,
                  d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                  vocab_size=64, compute_dtype="float32")


def _tree(rng, W=5):
    return {"a": jnp.asarray(rng.normal(size=(W, 8, 6)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(W, 40)), jnp.float32)}}


# ---------------------------------------------------------------------------
# codec round-trip / identity properties
# ---------------------------------------------------------------------------

class TestRoundTrip:
    def test_identity_exact(self, rng):
        t = _tree(rng)
        c = get_codec(CommConfig(codec="identity"))
        out = c.decode(c.encode(t), t)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert c.bits(t) == dense_bits(t)

    def test_signsgd_decode_is_scaled_sign(self, rng):
        t = _tree(rng)
        c = get_codec(CommConfig(codec="signsgd"))
        dec = c.decode(c.encode(t), t)
        for d, g in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
            M = np.asarray(g)
            # one scale per trailing row: mean |g| over the last axis
            scale = np.abs(M).mean(axis=-1, keepdims=True)
            np.testing.assert_allclose(np.asarray(d), np.sign(M) * scale,
                                       rtol=1e-6)

    def test_signsgd_majority_vote_unanimous(self, rng):
        # all workers share one sign pattern -> the vote reproduces it
        base = jnp.asarray(rng.normal(size=(30,)), jnp.float32)
        t = {"x": jnp.broadcast_to(base[None], (5, 30))}
        c = get_codec(CommConfig(codec="signsgd"))
        d = majority_vote(c.encode(t), t)
        np.testing.assert_array_equal(np.sign(np.asarray(d["x"])),
                                      np.sign(np.asarray(base)))
        assert d["x"].shape == (30,)

    def test_signsgd_majority_vote_byzantine_minority(self, rng):
        # 2 of 5 workers flip their signs; the honest majority wins every
        # coordinate (the per-coordinate breakdown point of the vote).
        base = jnp.asarray(rng.normal(size=(30,)) + 3.0, jnp.float32)
        honest = jnp.broadcast_to(base[None], (3, 30))
        t = {"x": jnp.concatenate([-honest[:2], honest], axis=0)}
        c = get_codec(CommConfig(codec="signsgd"))
        d = majority_vote(c.encode(t), t)
        np.testing.assert_array_equal(np.sign(np.asarray(d["x"])),
                                      np.sign(np.asarray(base)))

    def test_topk_keeps_largest(self, rng):
        t = _tree(rng)
        c = get_codec(CommConfig(codec="topk", topk_density=0.25))
        dec = c.decode(c.encode(t), t)
        for d, g in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
            Wd = g.shape[0]
            M = np.asarray(g.reshape(Wd, -1))
            D = np.asarray(d.reshape(Wd, -1))
            n = M.shape[1]
            k = max(1, round(0.25 * n))
            for w in range(Wd):
                nz = np.nonzero(D[w])[0]
                assert len(nz) == k
                # kept entries match the source values...
                np.testing.assert_allclose(D[w, nz], M[w, nz], rtol=1e-6)
                # ...and are exactly the k largest magnitudes
                thresh = np.sort(np.abs(M[w]))[-k]
                assert (np.abs(M[w, nz]) >= thresh - 1e-6).all()

    def test_topk_sparse_fixed_point(self, rng):
        # a tree that is already k-sparse round-trips exactly
        c = get_codec(CommConfig(codec="topk", topk_density=0.1))
        dense = np.zeros((4, 50), np.float32)
        k = 5
        for w in range(4):
            idx = rng.choice(50, size=k, replace=False)
            dense[w, idx] = rng.normal(size=k) + np.sign(rng.normal(size=k))
        t = {"x": jnp.asarray(dense)}
        dec = c.decode(c.encode(t), t)
        np.testing.assert_allclose(np.asarray(dec["x"]), dense, rtol=1e-6)

    def test_countsketch_gram_unbiased(self):
        # own generator: statistical tolerances must not depend on how much
        # of the session-scoped rng stream earlier test modules consumed
        rng = np.random.default_rng(42)
        x = rng.normal(size=(1, 256)).astype(np.float32)
        y = rng.normal(size=(1, 256)).astype(np.float32)
        dots = []
        for seed in range(64):
            c = get_codec(CommConfig(codec="countsketch", sketch_ratio=0.25,
                                     seed=seed))
            sx = c.encode({"x": jnp.asarray(x)})[0]
            sy = c.encode({"x": jnp.asarray(y)})[0]
            dots.append(float(np.asarray(sx @ sy.T).ravel()[0]))
        true = float((x @ y.T).ravel()[0])
        norm = np.linalg.norm(x) * np.linalg.norm(y)
        assert abs(np.mean(dots) - true) / norm < 0.05

    def test_countsketch_unsketch_unbiased(self):
        rng = np.random.default_rng(43)
        x = rng.normal(size=(1, 256)).astype(np.float32)
        recs = []
        for seed in range(64):
            c = get_codec(CommConfig(codec="countsketch", sketch_ratio=0.25,
                                     seed=seed))
            payload = c.encode({"x": jnp.asarray(x)})
            recs.append(np.asarray(c.decode(payload, {"x": jnp.asarray(x)})["x"]))
        rec = np.mean(recs, axis=0)
        assert np.linalg.norm(rec - x) / np.linalg.norm(x) < 0.45

    def test_unknown_codec_raises(self):
        with pytest.raises(KeyError):
            get_codec(CommConfig(codec="zstd"))


# ---------------------------------------------------------------------------
# cost models
# ---------------------------------------------------------------------------

class TestBits:
    def test_ratios(self, rng):
        # a production-shaped tree: big leaves, so per-leaf overheads wash out
        t = {"emb": jnp.zeros((8, 64, 128)), "mlp": jnp.zeros((8, 16384))}
        dense = dense_bits(t)
        ratio = {name: dense / get_codec(CommConfig(codec=name)).bits(t)
                 for name in CODECS}
        assert ratio["identity"] == 1.0
        assert ratio["signsgd"] > 20.0          # 1 bit + 32/d_last per coord
        assert ratio["topk"] > 8.0              # 1/16 coords x (32 + idx) bits
        assert ratio["countsketch"] >= 15.9     # ratio 1/16 fp32 buckets
        # the acceptance bound: every non-identity codec saves >= 8x
        assert all(r >= 8.0 for n, r in ratio.items() if n != "identity")

    def test_bits_are_static(self, rng):
        t = _tree(rng)
        for name in CODECS:
            b = get_codec(CommConfig(codec=name)).bits(t)
            assert isinstance(b, float) and b > 0


# ---------------------------------------------------------------------------
# error feedback: generative mean recovery
# ---------------------------------------------------------------------------

CASE = st.tuples(st.integers(3, 8),      # workers
                 st.integers(40, 400),   # coords
                 st.integers(0, 1))      # codec: 0=signsgd 1=topk


class TestErrorFeedback:
    @settings(max_examples=8, deadline=None)
    @given(CASE)
    def test_ef_mean_recovery(self, case):
        """EF telescopes: the running mean of decoded messages converges to
        the true (fixed) gradient at rate ||e_T|| / T, for biased codecs."""
        w, n, which = case
        codec = get_codec(CommConfig(codec=("signsgd", "topk")[which]))
        rng = np.random.default_rng(1000 * w + n)
        g = {"x": jnp.asarray(rng.normal(size=(w, n)), jnp.float32)}
        ef = jax.tree.map(jnp.zeros_like, g)
        acc = jnp.zeros_like(g["x"])
        errs = {}
        for t in range(1, 65):
            dec, _, ef = ef_encode_decode(codec, g, ef)
            acc = acc + dec["x"]
            if t in (8, 64):
                errs[t] = float(jnp.linalg.norm(acc / t - g["x"])
                                / jnp.linalg.norm(g["x"]))
        assert errs[64] < 0.2, errs
        assert errs[64] < errs[8], errs      # O(1/T) decay, not a plateau

    def test_ef_none_passthrough(self, rng):
        t = _tree(rng)
        codec = get_codec(CommConfig(codec="signsgd"))
        dec, payload, new_ef = ef_encode_decode(codec, t, None)
        assert new_ef is None
        ref = codec.decode(codec.encode(t), t)
        for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_missing_ef_raises(self, rng):
        t = _tree(rng)
        with pytest.raises(ValueError, match="error feedback"):
            compressed_aggregate(t, AggregatorConfig(name="mean"),
                                 CommConfig(codec="signsgd"), None)

    def test_coordwise_rejects_gram(self, rng):
        t = _tree(rng)
        with pytest.raises(ValueError, match="coordinate-wise"):
            aggregate_tree(t, AggregatorConfig(name="median"),
                           gram=jnp.eye(5))


# ---------------------------------------------------------------------------
# bridge routing
# ---------------------------------------------------------------------------

class TestBridge:
    def test_none_matches_plain(self, rng):
        t = _tree(rng)
        cfg = AggregatorConfig(name="flag", flag=FlagConfig(lam=2.0))
        d0, _ = aggregate_tree(t, cfg)
        d1, aux, ef = compressed_aggregate(t, cfg, CommConfig(), None)
        assert ef is None and float(aux["comm_ratio"]) == 1.0
        for a, b in zip(jax.tree.leaves(d0), jax.tree.leaves(d1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_sketch_gram_aggregate_close(self):
        """CountSketch-fed FA reproduces the exact-Gram aggregate direction
        and keeps the Byzantine worker suppressed.  (Raw combination
        weights are ill-conditioned when honest gradients nearly coincide
        — the subspace can rotate freely inside the honest cluster — so
        the stable invariants are the *aggregate* and the attacker's
        share, not the weight vector itself.)"""
        rng = np.random.default_rng(44)
        byz = rng.uniform(-8, 8, size=(1, 512))
        honest = np.ones((5, 512)) + 0.05 * rng.normal(size=(5, 512))
        t = {"x": jnp.asarray(np.concatenate([byz, honest], axis=0),
                              jnp.float32)}
        cfg = AggregatorConfig(name="flag", flag=FlagConfig(lam=0.0,
                                                            regularizer="none"))
        d0, _ = aggregate_tree(t, cfg)
        d1, aux1, _ = compressed_aggregate(
            t, cfg, CommConfig(codec="countsketch", sketch_ratio=0.5), None)
        a = np.asarray(d0["x"]).ravel()
        b = np.asarray(d1["x"]).ravel()
        cos = a @ b / (np.linalg.norm(a) * np.linalg.norm(b))
        assert cos > 0.99, cos
        w1 = np.abs(np.asarray(aux1["weights"]))
        assert w1[0] / w1.sum() < 0.1    # Byzantine stays suppressed

    def test_sketch_decode_path_for_coordwise(self, rng):
        t = _tree(rng)
        d, aux, _ = compressed_aggregate(
            t, AggregatorConfig(name="median", f=1),
            CommConfig(codec="countsketch", sketch_ratio=0.5), None)
        assert all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree.leaves(d))
        assert float(aux["comm_ratio"]) > 1.5

    def test_sketch_explicit_ef_routes_to_decode(self, rng):
        """error_feedback=True on a gram-feeding codec opts out of the
        gram fast path: the EF memory must actually update (a dead
        pass-through buffer would silently pretend EF is active)."""
        t = _tree(rng)
        comm = CommConfig(codec="countsketch", sketch_ratio=0.25,
                          error_feedback=True)
        assert comm.wants_ef
        ef0 = jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), t)
        _, _, ef1 = compressed_aggregate(
            t, AggregatorConfig(name="flag", flag=FlagConfig(lam=2.0)),
            comm, ef0)
        moved = sum(float(jnp.max(jnp.abs(a)))
                    for a in jax.tree.leaves(ef1))
        assert moved > 0.0


# ---------------------------------------------------------------------------
# codec x aggregator through the real train step
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lockstep_batch():
    one = SyntheticLM(vocab_size=CFG.vocab_size).batch(
        jax.random.PRNGKey(7), B, S)
    return {k: jnp.broadcast_to(v[None], (W,) + v.shape)
            for k, v in one.items()}


@pytest.fixture(scope="module")
def train_state():
    return init_train_state(jax.random.PRNGKey(0), CFG, sgd(momentum=0.9))


def _comm_step(train_state, batch, agg_name, codec, steps=1):
    params, opt_state = train_state
    comm = CommConfig(codec=codec)
    tc = TrainConfig(
        aggregator=AggregatorConfig(name=agg_name, f=F,
                                    flag=FlagConfig(lam=float(W))),
        attack="sign_flip", attack_f=F, comm=comm)
    step = jax.jit(build_train_step(CFG, tc, sgd(momentum=0.9),
                                    constant(1e-3)))
    ef = init_ef(params, W) if comm.wants_ef else None
    m = None
    for t in range(steps):
        args = (params, opt_state, batch, jax.random.PRNGKey(100 + t),
                jnp.asarray(t, jnp.int32))
        if comm.wants_ef:
            params, opt_state, m, ef = step(*args, ef)
        else:
            params, opt_state, m = step(*args)
    return params, m


@pytest.mark.parametrize("agg", ["flag", "krum", "mean"])
@pytest.mark.parametrize("codec", ["signsgd", "topk", "countsketch"])
class TestTrainStepCodecs:
    def test_finite(self, lockstep_batch, train_state, agg, codec):
        p1, m = _comm_step(train_state, lockstep_batch, agg, codec)
        assert bool(jnp.isfinite(m["loss"]))
        assert bool(jnp.isfinite(m["grad_global_norm"]))
        assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
                   for l in jax.tree.leaves(p1))
        assert float(m["comm_ratio"]) >= 8.0
        assert float(m["comm_bits"]) > 0


@pytest.mark.slow
class TestCompressedConvergence:
    """Acceptance bound: EF-compressed lockstep training within 5% of the
    uncompressed final loss (mini version of examples/byzantine_train.py's
    --lockstep --attack sign_flip config; the example itself is the
    full-scale check).  Marked slow (3 x 25 compiled train steps) so the
    gating CI lane keeps its ~2 min budget."""

    N_STEPS = 25

    def _loss(self, codec, lockstep_batch, train_state):
        task = SyntheticLM(vocab_size=CFG.vocab_size)
        params, opt_state = train_state
        comm = CommConfig(codec=codec)
        tc = TrainConfig(
            aggregator=AggregatorConfig(name="flag", f=F,
                                        flag=FlagConfig(lam=float(W))),
            attack="sign_flip", attack_f=F, comm=comm)
        step = jax.jit(build_train_step(CFG, tc, sgd(momentum=0.9),
                                        constant(5e-3)))
        ef = init_ef(params, W) if comm.wants_ef else None
        for t in range(self.N_STEPS):
            one = task.batch(jax.random.fold_in(jax.random.PRNGKey(5), t),
                             B, S)
            batch = {k: jnp.broadcast_to(v[None], (W,) + v.shape)
                     for k, v in one.items()}
            args = (params, opt_state, batch, jax.random.PRNGKey(200 + t),
                    jnp.asarray(t, jnp.int32))
            if comm.wants_ef:
                params, opt_state, m, ef = step(*args, ef)
            else:
                params, opt_state, m = step(*args)
        return float(m["loss"]), float(m["comm_ratio"])

    def test_ef_codecs_track_uncompressed(self, lockstep_batch, train_state):
        base, _ = self._loss("none", lockstep_batch, train_state)
        for codec in ("signsgd", "topk"):
            loss, ratio = self._loss(codec, lockstep_batch, train_state)
            assert ratio >= 8.0
            assert loss <= base * 1.05, \
                f"{codec}: loss {loss:.4f} > 1.05 * uncompressed {base:.4f}"


# ---------------------------------------------------------------------------
# hlo_stats: the sketch feeds the Gram path, no decoded stack
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestSketchGramHlo:
    def test_no_decoded_stack_materialized(self, lockstep_batch, train_state):
        """The (countsketch, flag) step's dot FLOPs drop vs. the dense step
        by exactly the Gram term 2 W^2 (n - k): the Gram contraction runs
        over sketch coordinates, and no decode reconstructs a (W, n) stack
        (which would *add* work instead of removing it)."""
        from repro.analysis import parse_cost
        params, opt_state = train_state

        def lower(codec):
            tc = TrainConfig(
                aggregator=AggregatorConfig(name="flag",
                                            flag=FlagConfig(lam=float(W))),
                comm=CommConfig(codec=codec))
            step = jax.jit(build_train_step(CFG, tc, sgd(momentum=0.9),
                                            constant(1e-3)))
            lowered = step.lower(params, opt_state, lockstep_batch,
                                 jax.random.PRNGKey(0),
                                 jnp.zeros((), jnp.int32))
            return parse_cost(lowered.compile().as_text())

        dense = lower("none")
        sketch = lower("countsketch")
        assert sketch.flops < dense.flops

        ratio = CommConfig().sketch_ratio
        n_leaves = [int(l.size // W)
                    for l in jax.tree.leaves(init_ef(params, W))]
        n_total = sum(n_leaves)
        k_total = sum(max(1, min(n, round(ratio * n))) for n in n_leaves)
        expected_delta = 2.0 * W * W * (n_total - k_total)
        delta = dense.flops - sketch.flops
        assert abs(delta - expected_delta) / expected_delta < 0.25, \
            (delta, expected_delta)
