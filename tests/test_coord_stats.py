"""Property + equivalence suite for the coordinate selection network.

Tier-1 (NOT ``slow``): this is the gating coverage for the
`kernels/coord_stats` production path — the odd-even selection network in
both its lowerings (the Pallas kernel in interpret mode, and the fused XLA
network in `net.py`) against the jnp.sort references, across worker counts
W in {3..64} x trim widths f in {0..(W-1)//2}, with adversarial data
(duplicates, ties, signed zeros, bf16) and dynamic membership masks.

Generation is property-based via hypothesis, with the deterministic
`tests/_hypothesis_fallback.py` shim in hermetic environments — >=40
generated cases run in the tier-1 lane either way.

Also pins the single-source contract: the reference stats in
``kernels/coord_stats/ref.py`` ARE the implementations behind
``core/aggregators.py`` (identity-checked, so they can never drift).

Process isolation: like ``tests/test_sharded_agg.py``, the module runs
its assertions in a subprocess spawned by the one non-skipped launcher
test.  The suite compiles ~50 interpret-mode Pallas programs; letting
those accumulate in the same process as the rest of the tier-1 lane's
compilations (hundreds of programs, including the transformer decode
scans) reproducibly segfaults XLA:CPU's compiler later in the session —
isolating the kernel sweep sidesteps the landmine without dropping any
coverage from the gating lane.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

IN_SUBPROCESS = os.environ.get("REPRO_COORD_STATS_SUBPROCESS") == "1"
in_subprocess = pytest.mark.skipif(
    not IN_SUBPROCESS, reason="runs in the subprocess spawned by "
                              "test_runs_in_isolated_subprocess")


def test_runs_in_isolated_subprocess():
    """Tier-1 entry point: execute this module's suite in its own
    process (see the module docstring for why)."""
    if IN_SUBPROCESS:
        pytest.skip("already inside the isolated run")
    env = dict(os.environ)
    env["REPRO_COORD_STATS_SUBPROCESS"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    repo = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        env=env, cwd=repo, capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, f"coord_stats suite failed in the " \
                              f"isolated subprocess:\n{r.stdout}\n{r.stderr}"

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # hermetic env
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import aggregators as agg
from repro.dist.aggregation import AggregatorConfig, aggregate_tree
from repro.kernels.coord_stats import ref as cs_ref
from repro.kernels.coord_stats.kernel import (
    bulyan_select_pallas,
    coord_stats_pallas,
    krum_scores_pallas,
)
from repro.kernels.coord_stats.net import coord_stats_net
from repro.kernels.coord_stats.ops import (
    COORD_OPS,
    bulyan_select,
    krum_scores,
)

_REF = {"median": lambda X, f: cs_ref.median_ref(X),
        "trimmed_mean": cs_ref.trimmed_mean_ref,
        "meamed": cs_ref.meamed_ref,
        "phocas": cs_ref.phocas_ref}


def _data(rng, W: int, n: int, mode: int) -> np.ndarray:
    """Adversarial input families: 0 gaussian, 1 heavy duplicates/ties,
    2 signed zeros + repeated magnitudes."""
    if mode == 0:
        x = rng.normal(size=(W, n))
    elif mode == 1:
        x = rng.integers(-3, 4, size=(W, n)).astype(np.float64)
    else:
        x = rng.choice(np.array([-1.0, -0.0, 0.0, 1.0]), size=(W, n))
    return x.astype(np.float32)


def _case_rng(*parts):
    return np.random.default_rng(np.abs(hash(parts)) % (2**32))


@in_subprocess
class TestSelectionNetworkVsRefs:
    """Pallas kernel (interpret mode) == jnp.sort references."""

    CASE = st.tuples(st.integers(3, 64),      # W (odd and even)
                     st.integers(0, 10_000),  # f seed -> f in 0..(W-1)//2
                     st.integers(0, 3),       # op index
                     st.integers(0, 2))       # data family

    @settings(max_examples=20, deadline=None)
    @given(CASE)
    def test_kernel_matches_ref(self, case):
        W, fseed, op_i, mode = case
        f = fseed % ((W - 1) // 2 + 1)
        op = COORD_OPS[op_i]
        X = _data(_case_rng("unmasked", *case), W, 97, mode)
        got = coord_stats_pallas(X, op=op, f=f, block_n=128, interpret=True)
        want = _REF[op](jnp.asarray(X), f)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    BF16_CASE = st.tuples(st.integers(3, 24), st.integers(0, 10_000),
                          st.integers(0, 3))

    @settings(max_examples=8, deadline=None)
    @given(BF16_CASE)
    def test_kernel_bf16(self, case):
        """bf16 inputs: the kernel upcasts tiles to fp32, so the oracle is
        the fp32 reference on the same bf16 values (computing the ref in
        bf16 instead can legitimately pick a different nearest-set at the
        selection boundary)."""
        W, fseed, op_i = case
        f = fseed % ((W - 1) // 2 + 1)
        op = COORD_OPS[op_i]
        X = _data(_case_rng("bf16", *case), W, 96, 0)
        X16 = jnp.asarray(X, jnp.bfloat16)
        got = coord_stats_pallas(X16, op=op, f=f, block_n=128,
                                 interpret=True)
        want = _REF[op](X16.astype(jnp.float32), f)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@in_subprocess
class TestMaskedNetwork:
    """Masked kernel == the ``masked_*`` references == dense subset."""

    CASE = st.tuples(st.integers(3, 32),      # W
                     st.integers(0, 10_000),  # f seed
                     st.integers(0, 3),       # op index
                     st.integers(0, 10_000),  # active-count seed -> 1..W
                     st.integers(0, 2))       # data family

    @settings(max_examples=16, deadline=None)
    @given(CASE)
    def test_masked_kernel_matches_masked_ref(self, case):
        W, fseed, op_i, waseed, mode = case
        f = fseed % ((W - 1) // 2 + 1)
        op = COORD_OPS[op_i]
        rng = _case_rng("masked", *case)
        X = _data(rng, W, 97, mode)
        wa = waseed % W + 1
        mask = np.zeros(W, np.float32)
        mask[rng.choice(W, wa, replace=False)] = 1.0
        got = coord_stats_pallas(X, jnp.asarray(mask), op=op, f=f,
                                 block_n=128, interpret=True)
        want = agg.MASKED_COORDWISE[op](jnp.asarray(X), jnp.asarray(mask),
                                        f=f)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("op", COORD_OPS)
    def test_every_active_count_equals_dense_subset(self, op):
        """For every active count 1..W: masked op == unmasked op on the
        dense active submatrix (the test_membership.py invariant), and the
        kernel agrees with the masked reference at every count without a
        shape change (same compiled program serves all subsets)."""
        W, f = 9, 2
        rng = np.random.default_rng(42)
        X = rng.normal(size=(W, 130)).astype(np.float32)
        for wa in range(1, W + 1):
            mask = np.zeros(W, np.float32)
            active = rng.choice(W, wa, replace=False)
            mask[active] = 1.0
            dense = _REF[op](jnp.asarray(X[np.sort(active)]), f)
            masked = agg.MASKED_COORDWISE[op](jnp.asarray(X),
                                              jnp.asarray(mask), f=f)
            kernel = coord_stats_pallas(X, jnp.asarray(mask), op=op, f=f,
                                        block_n=128, interpret=True)
            np.testing.assert_allclose(np.asarray(masked), np.asarray(dense),
                                       rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(np.asarray(kernel), np.asarray(masked),
                                       rtol=1e-5, atol=1e-5)


@in_subprocess
class TestNetLowering:
    """net.py (the fused XLA lowering) is result-identical to the kernel."""

    @pytest.mark.parametrize("op", COORD_OPS)
    def test_net_matches_interpret_kernel(self, op):
        """Same selections; trimmed/mean-around sums may associate fp32
        adds differently between the two lowerings (median is bitwise)."""
        rng = np.random.default_rng(3)
        X = rng.normal(size=(11, 201)).astype(np.float32)
        a = coord_stats_net(jnp.asarray(X), op=op, f=2)
        b = coord_stats_pallas(X, op=op, f=2, block_n=128, interpret=True)
        if op == "median":
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-6, atol=5e-6)

    def test_net_masked_matches_interpret_kernel(self):
        rng = np.random.default_rng(4)
        X = rng.normal(size=(10, 150)).astype(np.float32)
        mask = np.array([1, 0, 1, 1, 0, 1, 1, 1, 0, 1], np.float32)
        for op in COORD_OPS:
            a = coord_stats_net(jnp.asarray(X), jnp.asarray(mask), op=op,
                                f=2)
            b = coord_stats_pallas(X, jnp.asarray(mask), op=op, f=2,
                                   block_n=128, interpret=True)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-6, atol=5e-6)

    def test_kernel_block_size_invariance(self):
        """Chunk streaming: the grid split over n never changes results."""
        rng = np.random.default_rng(5)
        X = rng.normal(size=(7, 700)).astype(np.float32)
        for op in COORD_OPS:
            a = coord_stats_pallas(X, op=op, f=1, block_n=128,
                                   interpret=True)
            b = coord_stats_pallas(X, op=op, f=1, block_n=512,
                                   interpret=True)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@in_subprocess
class TestSelectionKernels:
    """Fused Krum / Bulyan distance-selection kernels vs the references."""

    @pytest.mark.parametrize("p,f", [(7, 1), (15, 3), (16, 2), (9, 2)])
    def test_krum_scores(self, p, f):
        rng = np.random.default_rng(p * 10 + f)
        G = rng.normal(size=(p, 40)).astype(np.float32)
        D2 = agg.pairwise_sq_dists(jnp.asarray(G))
        got = krum_scores_pallas(D2, f=f, interpret=True)
        want = agg.krum_scores(D2, f)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("p,f", [(7, 1), (15, 3), (16, 2), (9, 2)])
    def test_bulyan_select_order(self, p, f):
        """Same picks in the same (lowest-score-first) selection order."""
        rng = np.random.default_rng(p * 100 + f)
        G = rng.normal(size=(p, 40)).astype(np.float32)
        D2 = agg.pairwise_sq_dists(jnp.asarray(G))
        got = bulyan_select_pallas(D2, f=f, interpret=True)
        want = agg.bulyan_select(D2, f)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ops_dispatch(self):
        rng = np.random.default_rng(11)
        G = rng.normal(size=(12, 64)).astype(np.float32)
        D2 = agg.pairwise_sq_dists(jnp.asarray(G))
        np.testing.assert_allclose(
            np.asarray(krum_scores(D2, f=2, impl="pallas_interpret")),
            np.asarray(krum_scores(D2, f=2, impl="xla")),
            rtol=1e-5, atol=1e-4)
        np.testing.assert_array_equal(
            np.asarray(bulyan_select(D2, f=2, impl="pallas_interpret")),
            np.asarray(bulyan_select(D2, f=2, impl="xla")))


@in_subprocess
class TestSingleSource:
    """Satellite 4: kernels/coord_stats/ref.py is the single source for the
    coordinate stats — core/aggregators must *be* those functions."""

    def test_aggregators_import_the_refs(self):
        assert agg.median_ref is cs_ref.median_ref
        assert agg.trimmed_mean_ref is cs_ref.trimmed_mean_ref
        assert agg.mean_around_ref is cs_ref.mean_around_ref
        assert agg.meamed_ref is cs_ref.meamed_ref
        assert agg.phocas_ref is cs_ref.phocas_ref

    @pytest.mark.parametrize("f", [0, 1, 3, 7, 50])
    def test_behavioural_equality_with_clamping(self, f):
        """Public aggregators == refs for every f, including over-aggressive
        values that exercise the clamps (f >= (p-1)//2, f >= p)."""
        rng = np.random.default_rng(f)
        X = jnp.asarray(rng.normal(size=(9, 80)).astype(np.float32))
        np.testing.assert_array_equal(np.asarray(agg.median(X)),
                                      np.asarray(cs_ref.median_ref(X)))
        np.testing.assert_array_equal(
            np.asarray(agg.trimmed_mean(X, f=f)),
            np.asarray(cs_ref.trimmed_mean_ref(X, f)))
        np.testing.assert_array_equal(np.asarray(agg.meamed(X, f=f)),
                                      np.asarray(cs_ref.meamed_ref(X, f)))
        np.testing.assert_array_equal(np.asarray(agg.phocas(X, f=f)),
                                      np.asarray(cs_ref.phocas_ref(X, f)))


@in_subprocess
class TestAggregateTreeDispatch:
    """impl= routes end-to-end through aggregate_tree (tier-1 interpret
    coverage for the kernel path — the un-slow satellite)."""

    def _tree(self, W=9):
        rng = np.random.default_rng(0)
        return {"a": jnp.asarray(rng.normal(size=(W, 300)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(W, 17, 5)), jnp.float32)}

    @pytest.mark.parametrize("name", sorted(COORD_OPS) + ["bulyan"])
    def test_pallas_interpret_equals_xla(self, name):
        tree = self._tree()
        d_x, aux_x = aggregate_tree(tree, AggregatorConfig(name=name, f=2))
        d_p, aux_p = aggregate_tree(
            tree, AggregatorConfig(name=name, f=2, impl="pallas_interpret"))
        for k in tree:
            np.testing.assert_allclose(np.asarray(d_p[k]),
                                       np.asarray(d_x[k]),
                                       rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(aux_p["weights"]),
                                   np.asarray(aux_x["weights"]),
                                   rtol=1e-6, atol=1e-6)

    def test_pallas_interpret_masked_equals_xla(self):
        tree = self._tree()
        mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0, 1], jnp.float32)
        for name in ("median", "meamed", "bulyan"):
            d_x, _ = aggregate_tree(
                tree, AggregatorConfig(name=name, f=2), mask=mask)
            d_p, _ = aggregate_tree(
                tree, AggregatorConfig(name=name, f=2,
                                       impl="pallas_interpret"), mask=mask)
            for k in tree:
                np.testing.assert_allclose(np.asarray(d_p[k]),
                                           np.asarray(d_x[k]),
                                           rtol=1e-5, atol=1e-5)

    def test_krum_family_pallas_interpret(self):
        tree = self._tree()
        for name in ("krum", "multi_krum"):
            d_x, aux_x = aggregate_tree(tree,
                                        AggregatorConfig(name=name, f=2))
            d_p, aux_p = aggregate_tree(
                tree, AggregatorConfig(name=name, f=2,
                                       impl="pallas_interpret"))
            np.testing.assert_allclose(np.asarray(aux_p["weights"]),
                                       np.asarray(aux_x["weights"]),
                                       rtol=1e-6, atol=1e-6)
            for k in tree:
                np.testing.assert_allclose(np.asarray(d_p[k]),
                                           np.asarray(d_x[k]),
                                           rtol=1e-5, atol=1e-5)
