"""Unit tests for the Flag Aggregator core (dense reference + Gram form)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FlagConfig, beta_mle, default_m, fa_weights_from_gram,
                        flag_aggregate, flag_aggregate_gram, flag_subspace,
                        gram_matrix)
from tests.conftest import make_gradient_matrix

jax.config.update("jax_enable_x64", False)


class TestBetaMLE:
    def test_taylor_log_approximates_log(self):
        x = jnp.linspace(0.05, 1.0, 50)
        for a in (8.0, 32.0, 128.0):
            err = jnp.max(jnp.abs(beta_mle.taylor_log(x, a) - jnp.log(x)))
            assert err < 5.0 / a  # error shrinks like O(1/a)

    def test_paper_default_is_sqrt_loss(self):
        v = jnp.linspace(0.0, 0.999, 64)
        t = beta_mle.beta_nll_terms(v, alpha=1.0, beta=0.5, a=2.0)
        np.testing.assert_allclose(t, jnp.sqrt(1.0 - v), atol=1e-3)

    def test_irls_weights_paper_default(self):
        v = jnp.array([0.0, 0.5, 0.99])
        w = beta_mle.irls_weights(v, jnp.ones(3))
        np.testing.assert_allclose(w, 0.5 / jnp.sqrt(1.0 - v), rtol=1e-5)

    def test_irls_weights_monotone_in_v(self):
        v = jnp.linspace(0.0, 0.999, 100)
        w = beta_mle.irls_weights(v, jnp.ones_like(v))
        assert bool(jnp.all(jnp.diff(w) >= 0))


class TestDefaultM:
    @pytest.mark.parametrize("p,expect", [(15, 8), (7, 4), (60, 31), (2, 2)])
    def test_paper_formula(self, p, expect):
        assert default_m(p) == expect


class TestFlagSubspace:
    def test_orthonormal(self, grad_matrix):
        Y, aux = flag_subspace(jnp.asarray(grad_matrix.T))
        np.testing.assert_allclose(np.asarray(Y.T @ Y), np.eye(aux["m"]),
                                   atol=1e-4)

    def test_explained_variance_range(self, grad_matrix):
        _, aux = flag_subspace(jnp.asarray(grad_matrix.T))
        v = np.asarray(aux["explained_variance"])
        assert v.shape == (grad_matrix.shape[0],)
        assert (v >= 0).all() and (v <= 1 + 1e-6).all()

    def test_m_one_matches_dominant_direction(self, rng):
        # All workers identical => Y (m=1) must be that direction.
        g = rng.normal(size=(64,)).astype(np.float32)
        G = jnp.asarray(np.stack([g] * 6, axis=1))
        Y, _ = flag_subspace(G, FlagConfig(m=1, lam=0.0, regularizer="none"))
        cos = abs(float(Y[:, 0] @ g / np.linalg.norm(g)))
        assert cos > 1 - 1e-5

    def test_converges_within_budget(self, grad_matrix):
        _, aux = flag_subspace(jnp.asarray(grad_matrix.T), FlagConfig(n_iter=5))
        assert int(aux["iterations"]) <= 5


class TestDenseGramEquivalence:
    @pytest.mark.parametrize("lam", [0.0, 1.0, 15.0])
    @pytest.mark.parametrize("mode", ["raw", "clip", "unit"])
    def test_aggregate_matches(self, rng, lam, mode):
        Gw = make_gradient_matrix(rng, n=300, p=11, f=2)
        G = jnp.asarray(Gw.T)
        cfg = FlagConfig(lam=lam, norm_mode=mode)
        dd, _ = flag_aggregate(G, cfg)
        dg, _ = flag_aggregate_gram(G, cfg)
        scale = float(jnp.max(jnp.abs(dd))) + 1e-30
        assert float(jnp.max(jnp.abs(dd - dg))) / scale < 5e-3

    def test_weights_reproduce_update(self, grad_matrix):
        G = jnp.asarray(grad_matrix.T)
        cfg = FlagConfig(lam=15.0)
        c, _ = fa_weights_from_gram(gram_matrix(G), cfg)
        dd, _ = flag_aggregate(G, cfg)
        np.testing.assert_allclose(np.asarray(G @ c), np.asarray(dd),
                                   rtol=5e-2, atol=5e-3)


class TestRobustness:
    def test_byzantine_suppressed_clip_mode(self, rng):
        """Large-norm random Byzantine workers get ~zero combine weight."""
        Gw = make_gradient_matrix(rng, n=500, p=15, f=3, byz_scale=20.0)
        cfg = FlagConfig(lam=15.0, norm_mode="clip")
        c, _ = fa_weights_from_gram(gram_matrix(jnp.asarray(Gw.T)), cfg)
        c = np.asarray(c)
        assert np.abs(c[:3]).max() < 0.1 * np.abs(c[3:]).mean()

    def test_aggregate_close_to_honest_mean(self, rng):
        Gw = make_gradient_matrix(rng, n=500, p=15, f=3, byz_scale=20.0)
        d, _ = flag_aggregate_gram(jnp.asarray(Gw.T),
                                   FlagConfig(lam=15.0, norm_mode="clip"))
        hm = Gw[3:].mean(axis=0)
        rel = np.linalg.norm(np.asarray(d) - hm) / np.linalg.norm(hm)
        mean_rel = np.linalg.norm(Gw.mean(axis=0) - hm) / np.linalg.norm(hm)
        assert rel < 0.5 * mean_rel  # far better than the non-robust mean

    def test_no_byzantine_close_to_mean(self, rng):
        """f=0, concordant workers: FA approximately returns the mean.

        (Regime note, recorded in EXPERIMENTS.md: with lambda = Theta(p) and
        *diffuse* worker noise, the p(p-1)/2 pairwise-difference columns can
        out-mass the p data columns and rotate the subspace into noise space —
        so the sane default is lambda ~ 1 and worker agreement, which is the
        paper's own f=0 setting.)"""
        Gw = make_gradient_matrix(rng, n=400, p=10, f=0, noise=0.005)
        d, _ = flag_aggregate_gram(jnp.asarray(Gw.T), FlagConfig(lam=1.0))
        hm = Gw.mean(axis=0)
        rel = np.linalg.norm(np.asarray(d) - hm) / np.linalg.norm(hm)
        assert rel < 0.05


class TestConfigVariants:
    def test_l1_regularizer_runs(self, grad_matrix):
        d, _ = flag_aggregate(jnp.asarray(grad_matrix.T),
                              FlagConfig(lam=0.5, regularizer="l1"))
        assert bool(jnp.all(jnp.isfinite(d)))

    def test_general_beta_shapes(self, grad_matrix):
        G = jnp.asarray(grad_matrix.T)
        for alpha, beta, a in [(1.0, 0.5, 2.0), (2.0, 0.5, 2.0), (1.0, 0.25, 4.0)]:
            d, _ = flag_aggregate_gram(G, FlagConfig(alpha=alpha, beta=beta, a=a))
            assert bool(jnp.all(jnp.isfinite(d)))

    def test_jit_cache_stable(self, grad_matrix):
        G = jnp.asarray(grad_matrix.T)
        cfg = FlagConfig()
        d1, _ = flag_aggregate_gram(G, cfg)
        d2, _ = flag_aggregate_gram(G * 2.0, cfg)
        assert d1.shape == d2.shape
