"""Distribution-layer tests: tree aggregation == flat reference; end-to-end
train steps on every reduced arch (the per-arch smoke tests, deliverable f)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduce_for_smoke
from repro.core import FlagConfig, aggregators
from repro.dist.aggregation import (AggregatorConfig, aggregate_tree,
                                    tree_combine, tree_gram)
from repro.dist.train_step import TrainConfig, build_train_step, init_train_state
from repro.optim import adamw, constant, sgd


def _tree_of(rng, W):
    """Random worker-major pytree + its flattened (W, n) matrix."""
    tree = {"a": jnp.asarray(rng.normal(size=(W, 8, 6)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(W, 30)), jnp.float32),
                  "d": jnp.asarray(rng.normal(size=(W, 4, 3, 2)), jnp.float32)}}
    flat = jnp.concatenate([x.reshape(W, -1) for x in jax.tree.leaves(tree)],
                           axis=1)
    return tree, flat


class TestTreeAlgebra:
    def test_tree_gram_matches_flat(self, rng):
        tree, flat = _tree_of(rng, 7)
        K = tree_gram(tree)
        np.testing.assert_allclose(np.asarray(K), np.asarray(flat @ flat.T),
                                   rtol=1e-5, atol=1e-4)

    def test_tree_combine_matches_flat(self, rng):
        tree, flat = _tree_of(rng, 7)
        c = jnp.asarray(rng.normal(size=(7,)), jnp.float32)
        d = tree_combine(tree, c)
        dflat = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(d)])
        np.testing.assert_allclose(np.asarray(dflat), np.asarray(flat.T @ c),
                                   rtol=1e-5, atol=1e-4)

    def test_sketch_unbiased_diagonal(self, rng):
        tree, flat = _tree_of(rng, 5)
        K = tree_gram(tree, sketch_stride=2)
        K_full = tree_gram(tree)
        # sketch approximates; diagonal magnitudes within 2x
        ratio = np.asarray(jnp.diag(K) / jnp.diag(K_full))
        assert (ratio > 0.4).all() and (ratio < 2.5).all()


@pytest.mark.parametrize("name", ["mean", "flag", "pca", "median",
                                  "trimmed_mean", "meamed", "phocas",
                                  "krum", "multi_krum", "bulyan", "geomed"])
class TestTreeVsFlatAggregators:
    def test_equivalence(self, rng, name):
        """Tree aggregation == flat aggregation of the concatenated matrix."""
        W = 9
        tree, flat = _tree_of(rng, W)
        cfg = AggregatorConfig(name=name, f=2, flag=FlagConfig(lam=2.0))
        d_tree, _ = aggregate_tree(tree, cfg)
        d_tree_flat = jnp.concatenate([x.reshape(-1)
                                       for x in jax.tree.leaves(d_tree)])
        kwargs = {"f": 2} if name != "flag" else {"cfg": FlagConfig(lam=2.0)}
        d_flat = aggregators.get_aggregator(name)(flat, **kwargs)
        np.testing.assert_allclose(np.asarray(d_tree_flat),
                                   np.asarray(d_flat), rtol=2e-3, atol=2e-3)


class TestMicrobatchAccumulation:
    """Gradient accumulation (microbatch_splits > 1) must be a drop-in for
    the single-shot path: same output dtypes (the aggregator and comm_bits
    accounting see identical inputs regardless of k) and a clear error for
    indivisible batch sizes."""

    def _setup(self, B=4):
        local = np.random.default_rng(21)
        cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0)
        opt = sgd(momentum=0.9)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        batch = _smoke_batch(local, cfg, B=B)
        return cfg, opt, params, opt_state, batch

    def test_microbatch_matches_single_shot(self):
        cfg, opt, params, opt_state, batch = self._setup(B=4)
        outs = {}
        for k in (1, 2):
            tc = TrainConfig(aggregator=AggregatorConfig(name="mean"),
                             microbatch_splits=k)
            step = jax.jit(build_train_step(cfg, tc, opt, constant(1e-3)))
            p, _, m = step(params, opt_state, batch, jax.random.PRNGKey(1),
                           jnp.zeros((), jnp.int32))
            outs[k] = (p, m)
        for a, b in zip(jax.tree.leaves(outs[1][0]),
                        jax.tree.leaves(outs[2][0])):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(float(outs[1][1]["loss"]),
                                   float(outs[2][1]["loss"]), rtol=1e-5)
        assert float(outs[1][1]["comm_bits"]) == \
            float(outs[2][1]["comm_bits"])

    def test_indivisible_batch_raises_clearly(self):
        cfg, opt, params, opt_state, batch = self._setup(B=4)
        tc = TrainConfig(aggregator=AggregatorConfig(name="mean"),
                         microbatch_splits=3)
        step = build_train_step(cfg, tc, opt, constant(1e-3))
        with pytest.raises(ValueError, match="microbatch_splits=3 must "
                                             "divide"):
            jax.jit(step)(params, opt_state, batch, jax.random.PRNGKey(1),
                          jnp.zeros((), jnp.int32))


def _smoke_batch(rng, cfg, W=4, B=2, S=32):
    S_tok = S - (cfg.num_prefix_embeds if cfg.frontend else 0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (W, B, S_tok)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (W, B, S_tok)),
                              jnp.int32),
    }
    if cfg.frontend:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(W, B, cfg.num_prefix_embeds, cfg.d_frontend)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    """Deliverable (f): per-arch reduced-config smoke — one train step on
    CPU asserting output shapes + no NaNs, with FA aggregation on."""

    def test_train_step(self, rng, arch):
        cfg = reduce_for_smoke(get_config(arch))
        opt = sgd(momentum=0.9)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        tc = TrainConfig(
            aggregator=AggregatorConfig(name="flag",
                                        flag=FlagConfig(lam=4.0)),
            attack="random", attack_f=1)
        step_fn = jax.jit(build_train_step(cfg, tc, opt, constant(1e-3)))
        batch = _smoke_batch(rng, cfg)
        p1, o1, metrics = step_fn(params, opt_state, batch,
                                  jax.random.PRNGKey(1),
                                  jnp.zeros((), jnp.int32))
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_global_norm"]))
        # params actually moved
        moved = sum(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                          - b.astype(jnp.float32))))
                    for a, b in zip(jax.tree.leaves(p1),
                                    jax.tree.leaves(params)))
        assert moved > 0
        # shapes preserved
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(params)):
            assert a.shape == b.shape
        assert metrics["fa_weights"].shape == (4,)

    def test_loss_decreases(self, rng, arch):
        """A few FA steps on fixed data reduce the loss (system actually
        trains end-to-end, not just runs)."""
        cfg = reduce_for_smoke(get_config(arch))
        opt = adamw(weight_decay=0.0)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        # lam=0 at tiny worker counts: for p <= 4 the pairwise-difference
        # space has rank p-1 >= m, so the paper's lambda-regularized
        # objective is degenerate (the subspace collapses onto difference
        # directions and the aggregate vanishes) — quantified in
        # EXPERIMENTS.md §Repro "small-p degeneracy".
        tc = TrainConfig(aggregator=AggregatorConfig(
            name="flag", flag=FlagConfig(lam=0.0, regularizer="none")))
        step_fn = jax.jit(build_train_step(cfg, tc, opt, constant(3e-3)))
        batch = _smoke_batch(rng, cfg)
        losses = []
        for t in range(5):
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jax.random.PRNGKey(2),
                                           jnp.asarray(t, jnp.int32))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
