"""Rank-p IRLS solver + fused tree Gram tests.

Covers the PR-3 tentpole: (a) the rank-p solver matches both the dense
reference (``repro.core.flag``) and the retained q-space oracle across
p in {2..32}, all three norm_modes, and rank-deficient Grams; (b) the
default solver path never materializes an array with a q-sized dimension
(HLO shape inspection); (c) the fused tree Gram issues exactly one
``pallas_call`` for a multi-leaf pytree and matches the flat product.

All randomness comes from module-local ``np.random.default_rng``
generators so tolerances stay order-independent (no shared session rng).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import Graph, check_shape
from repro.core.flag import FlagConfig, flag_aggregate
from repro.core.gram import fa_weights_from_gram, gram_matrix
from repro.dist.aggregation import tree_combine, tree_gram
from repro.kernels.gram.ref import chunk_schedule, tree_gram_chunk_ref

PS = [2, 3, 5, 8, 16, 32]


def _gradients(p: int, n: int = 300, f: int | None = None, seed: int = 0,
               byz_scale: float = 20.0, noise: float = 0.3) -> np.ndarray:
    """(n, p) column-major gradient matrix, module-local rng."""
    rng = np.random.default_rng(seed + 97 * p)
    f = max(1, p // 5) if f is None else f
    mu = rng.normal(size=n)
    mu /= np.linalg.norm(mu)
    honest = mu[None, :] + noise * rng.normal(size=(p - f, n))
    byz = rng.uniform(-byz_scale, byz_scale, size=(f, n))
    return np.concatenate([byz, honest], axis=0).astype(np.float32).T


def _rel_err(a, b):
    scale = float(jnp.max(jnp.abs(a))) + 1e-30
    return float(jnp.max(jnp.abs(a - b))) / scale


class TestRankPEquivalence:
    """rank_p == qspace == dense across p, norm modes (paper lam = p)."""

    @pytest.mark.parametrize("p", PS)
    @pytest.mark.parametrize("mode", ["raw", "clip", "unit"])
    def test_matches_qspace_and_dense(self, p, mode):
        G = jnp.asarray(_gradients(p))
        cfg = FlagConfig(lam=float(p), norm_mode=mode)
        K = gram_matrix(G)
        cq, aq = fa_weights_from_gram(K, cfg, solver="qspace")
        cr, ar = fa_weights_from_gram(K, cfg, solver="rank_p")
        np.testing.assert_allclose(np.asarray(cr), np.asarray(cq), atol=2e-3)
        np.testing.assert_allclose(
            np.asarray(ar["explained_variance"]),
            np.asarray(aq["explained_variance"]), atol=2e-3)
        dd, _ = flag_aggregate(G, cfg)
        assert _rel_err(dd, G @ cr) < 5e-3

    @pytest.mark.parametrize("lam", [0.0, 1.0])
    @pytest.mark.parametrize("p", [8, 16])
    def test_small_lambda(self, p, lam):
        """Away from the small-p degenerate regime, small lam also agrees."""
        G = jnp.asarray(_gradients(p, seed=3))
        cfg = FlagConfig(lam=lam)
        K = gram_matrix(G)
        cq, _ = fa_weights_from_gram(K, cfg, solver="qspace")
        cr, _ = fa_weights_from_gram(K, cfg, solver="rank_p")
        np.testing.assert_allclose(np.asarray(cr), np.asarray(cq), atol=2e-3)

    def test_default_solver_is_rank_p(self):
        G = jnp.asarray(_gradients(11))
        cfg = FlagConfig(lam=11.0)
        K = gram_matrix(G)
        c_def, _ = fa_weights_from_gram(K, cfg)
        c_rp, _ = fa_weights_from_gram(K, cfg, solver="rank_p")
        np.testing.assert_array_equal(np.asarray(c_def), np.asarray(c_rp))

    def test_unknown_solver_raises(self):
        K = jnp.eye(4)
        with pytest.raises(ValueError, match="unknown solver"):
            fa_weights_from_gram(K, FlagConfig(), solver="nope")

    def test_rank_p_rejects_m_above_p(self):
        K = jnp.eye(4)
        with pytest.raises(ValueError, match="m=6 <= p=4"):
            fa_weights_from_gram(K, FlagConfig(m=6), solver="rank_p")

    @pytest.mark.parametrize("mode", ["raw", "clip", "unit"])
    def test_renormalize_weights_sum_to_one(self, mode):
        G = jnp.asarray(_gradients(9, seed=5))
        cfg = FlagConfig(lam=9.0, norm_mode=mode, renormalize=True)
        c, _ = fa_weights_from_gram(gram_matrix(G), cfg)
        assert abs(abs(float(jnp.sum(c))) - 1.0) < 1e-4


class TestRankDeficientGrams:
    """Duplicated / zero workers make K singular; both solvers must agree
    on the *aggregate* (the weight vector itself is not unique in the
    null space of K, so comparisons happen through G @ c)."""

    @pytest.mark.parametrize("mode", ["raw", "clip", "unit"])
    def test_duplicated_workers(self, mode):
        p = 8
        Gnp = _gradients(p, seed=11)
        Gnp[:, 3] = Gnp[:, 4]            # exact duplicate pair
        Gnp[:, 6] = Gnp[:, 5]
        G = jnp.asarray(Gnp)
        cfg = FlagConfig(lam=float(p), norm_mode=mode)
        K = gram_matrix(G)
        cq, _ = fa_weights_from_gram(K, cfg, solver="qspace")
        cr, _ = fa_weights_from_gram(K, cfg, solver="rank_p")
        assert bool(jnp.all(jnp.isfinite(cr)))
        dd, _ = flag_aggregate(G, cfg)
        assert _rel_err(G @ cq, G @ cr) < 5e-3
        assert _rel_err(dd, G @ cr) < 1e-2

    def test_zero_worker(self):
        p = 7
        Gnp = _gradients(p, seed=13)
        Gnp[:, 2] = 0.0
        G = jnp.asarray(Gnp)
        cfg = FlagConfig(lam=float(p))
        cr, aux = fa_weights_from_gram(gram_matrix(G), cfg, solver="rank_p")
        assert bool(jnp.all(jnp.isfinite(cr)))
        assert bool(jnp.all(jnp.isfinite(aux["explained_variance"])))
        cq, _ = fa_weights_from_gram(gram_matrix(G), cfg, solver="qspace")
        assert _rel_err(G @ cq, G @ cr) < 5e-3

    def test_all_identical_workers(self):
        """Rank-1 Gram: FA must reproduce the common direction."""
        rng = np.random.default_rng(17)
        g = rng.normal(size=200).astype(np.float32)
        G = jnp.asarray(np.stack([g] * 6, axis=1))
        c, _ = fa_weights_from_gram(gram_matrix(G), FlagConfig(lam=6.0),
                                    solver="rank_p")
        d = np.asarray(G @ c)
        cos = abs(d @ g) / (np.linalg.norm(d) * np.linalg.norm(g) + 1e-30)
        assert cos > 1 - 1e-5


class TestNoQSpaceArrays:
    """Acceptance: the default solver at p=32 allocates nothing with a
    dimension of size q = p + p(p-1)/2 = 528 (or any dim > p).

    The mechanism is the SHAPE rule of :mod:`repro.analysis` — this test
    only declares the bound; ``tools/jaxlint.py`` enforces the same
    invariant over the public entry-point sweep.
    """

    def _graph(self, solver, p=32):
        rng = np.random.default_rng(23)
        K = jnp.asarray(rng.normal(size=(4 * p, p)), jnp.float32)
        K = gram_matrix(K)
        cfg = FlagConfig(lam=float(p))
        fn = jax.jit(lambda k: fa_weights_from_gram(k, cfg, solver=solver))
        return Graph(f"fa_weights/{solver}", None,
                     fn.lower(K).compile().as_text())

    def test_rank_p_has_no_q_dim(self):
        p = 32
        findings = check_shape(self._graph("rank_p", p), max_dim=p,
                               require_dims={p})
        assert not findings, "\n".join(f.render() for f in findings)

    def test_qspace_oracle_does_have_q_dim(self):
        """Detector sanity: the q-space path *does* materialize q-dims."""
        p, q = 32, 32 + 32 * 31 // 2
        findings = check_shape(self._graph("qspace", p), max_dim=p)
        assert findings, "SHAPE rule missed the q-space oracle's q-dims"
        assert any(str(q) in f.message for f in findings)


def _tree(seed: int, W: int, sizes=((8, 6), (30,), (4, 3, 2))):
    rng = np.random.default_rng(seed)
    tree = {f"l{i}": jnp.asarray(rng.normal(size=(W,) + s), jnp.float32)
            for i, s in enumerate(sizes)}
    flat = jnp.concatenate([x.reshape(W, -1) for x in jax.tree.leaves(tree)],
                           axis=1)
    return tree, flat


class TestFusedTreeGram:
    def test_fused_matches_flat_exactly(self):
        tree, flat = _tree(29, W=7)
        K = tree_gram(tree)
        np.testing.assert_allclose(np.asarray(K), np.asarray(flat @ flat.T),
                                   rtol=1e-6, atol=1e-5)

    def test_fused_matches_looped(self):
        tree, _ = _tree(31, W=5)
        np.testing.assert_allclose(np.asarray(tree_gram(tree)),
                                   np.asarray(tree_gram(tree, fused=False)),
                                   rtol=1e-5, atol=1e-4)

    def test_single_pallas_call_for_multi_leaf_tree(self):
        """Acceptance: the fused tree Gram issues exactly one pallas_call
        for a multi-leaf pytree (the looped path issues one per leaf)."""
        tree, _ = _tree(37, W=4)
        assert len(jax.tree.leaves(tree)) == 3
        fused = jax.make_jaxpr(
            lambda t: tree_gram(t, impl="pallas_interpret"))(tree)
        assert str(fused).count("pallas_call") == 1
        looped = jax.make_jaxpr(
            lambda t: tree_gram(t, impl="pallas_interpret", fused=False))(tree)
        assert str(looped).count("pallas_call") == 3

    def test_pallas_interpret_matches_xla(self):
        tree, flat = _tree(41, W=6)
        K = tree_gram(tree, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(K), np.asarray(flat @ flat.T),
                                   rtol=1e-5, atol=1e-4)

    def test_sketch_small_input_is_exact(self):
        """Inputs under one chunk cannot be subsampled: scale must be 1."""
        tree, flat = _tree(43, W=5)
        K = tree_gram(tree, sketch_stride=4)
        np.testing.assert_allclose(np.asarray(K), np.asarray(flat @ flat.T),
                                   rtol=1e-6, atol=1e-5)

    def test_sketch_diagonal_unbiased_large_input(self):
        rng = np.random.default_rng(47)
        tree = {"x": jnp.asarray(rng.normal(size=(5, 37_000)), jnp.float32),
                "y": jnp.asarray(rng.normal(size=(5, 29_000)), jnp.float32)}
        K = tree_gram(tree)
        Ks = tree_gram(tree, sketch_stride=4)
        ratio = np.asarray(jnp.diag(Ks) / jnp.diag(K))
        assert (ratio > 0.8).all() and (ratio < 1.25).all()

    def test_sketch_same_subset_across_impls(self):
        """xla and pallas consume the identical chunk plan."""
        rng = np.random.default_rng(53)
        tree = {"x": jnp.asarray(rng.normal(size=(4, 9_000)), jnp.float32)}
        a = tree_gram(tree, sketch_stride=3)
        b = tree_gram(tree, sketch_stride=3, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-4)

    def test_bf16_in_fp32_accumulate(self):
        rng = np.random.default_rng(59)
        tree = {"x": jnp.asarray(rng.normal(size=(6, 5_000)), jnp.float32)}
        K = tree_gram(tree, gram_dtype="bfloat16")
        assert K.dtype == jnp.float32
        Kf = tree_gram(tree)
        assert _rel_err(Kf, K) < 2e-2

    def test_chunk_schedule_covers_and_scales(self):
        kept, n_pad, scale = chunk_schedule(10_000, 1024, 4)
        assert kept == 3                     # ceil(ceil(10000/1024)/4)
        assert n_pad >= 2 * 4 * 1024 + 1024
        covered = 1024 + 1024 + 1024
        assert scale == pytest.approx(10_000 / covered)
        kept1, _, scale1 = chunk_schedule(500, 1024, 8)
        assert kept1 == 1 and scale1 == 1.0

    def test_chunk_ref_matches_manual_subset(self):
        rng = np.random.default_rng(61)
        X = jnp.asarray(rng.normal(size=(3, 5_000)), jnp.float32)
        block, stride = 512, 2
        K = tree_gram_chunk_ref(X, sketch_stride=stride, block_n=block)
        kept, n_pad, scale = chunk_schedule(5_000, block, stride)
        Xp = np.zeros((3, n_pad), np.float32)
        Xp[:, :5_000] = np.asarray(X)
        sub = np.concatenate([Xp[:, j * stride * block:(j * stride * block)
                                 + block] for j in range(kept)], axis=1)
        np.testing.assert_allclose(np.asarray(K), scale * (sub @ sub.T),
                                   rtol=1e-5, atol=1e-4)


class TestLoopedSketchPath:
    """The ``fused=False`` per-leaf sketch path: the inverse-fraction
    rescale is applied to the fp32 Gram accumulator (never folded into a
    possibly-bf16 leaf matrix), and leaves narrower than the stride stay
    exact instead of inflating one surviving sample stride-fold."""

    def test_narrow_leaves_are_exact(self):
        """Every leaf narrower than the stride -> sketch is a no-op."""
        rng = np.random.default_rng(71)
        tree = {f"l{i}": jnp.asarray(rng.normal(size=(5, w)), jnp.float32)
                for i, w in enumerate([1, 2, 3, 7])}
        flat = jnp.concatenate([x for x in jax.tree.leaves(tree)], axis=1)
        K = tree_gram(tree, sketch_stride=8, fused=False)
        np.testing.assert_allclose(np.asarray(K), np.asarray(flat @ flat.T),
                                   rtol=1e-6, atol=1e-5)

    def test_ragged_leaves_looped_agrees_with_fused(self):
        """Ragged widths (sub-stride singletons next to wide leaves):
        looped and fused sample different deterministic subsets, but both
        must stay unbiased estimates of the same Gram — and of each
        other.  Under the old stride-based rescale the width-1/3 leaves
        were inflated stride-fold and the bias showed up here."""
        rng = np.random.default_rng(72)
        tree = {"a": jnp.asarray(rng.normal(size=(6, 1)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(6, 3)), jnp.float32),
                "c": jnp.asarray(rng.normal(size=(6, 20_000)), jnp.float32),
                "d": jnp.asarray(rng.normal(size=(6, 9_777)), jnp.float32)}
        K_full = np.asarray(tree_gram(tree))
        K_loop = np.asarray(tree_gram(tree, sketch_stride=4, fused=False))
        K_fuse = np.asarray(tree_gram(tree, sketch_stride=4))
        for K in (K_loop, K_fuse):
            ratio = np.diag(K) / np.diag(K_full)
            assert (ratio > 0.85).all() and (ratio < 1.18).all()
        scale = np.linalg.norm(K_full)
        assert np.linalg.norm(K_loop - K_fuse) / scale < 0.1
        assert np.linalg.norm(K_loop - K_full) / scale < 0.1

    def test_bf16_cast_does_not_truncate_rescale(self):
        """Integer-valued leaves are bf16-exact and the Gram accumulates
        in fp32, so the ONLY way the bf16 sketch can diverge from the
        fp32 sketch is a rescale folded into the matrix before the cast
        (the old ``sqrt(stride)`` bug).  Post-cast rescale -> bitwise
        equal."""
        rng = np.random.default_rng(73)
        vals = rng.integers(-8, 8, size=(4, 4096)).astype(np.float32)
        tree = {"x": jnp.asarray(vals)}
        K16 = tree_gram(tree, sketch_stride=3, gram_dtype="bfloat16",
                        fused=False)
        K32 = tree_gram(tree, sketch_stride=3, fused=False)
        np.testing.assert_array_equal(np.asarray(K16), np.asarray(K32))


class TestTreeCombinePrecision:
    def test_bf16_weights_not_truncated(self):
        """Combine weights must enter the contraction in fp32: offsets far
        below bf16 resolution around 1.0 must survive into the output."""
        W, n = 8, 64
        offs = np.linspace(-2e-3, 2e-3, W).astype(np.float32)
        c = jnp.asarray(1.0 + offs)
        tree = {"l": jnp.ones((W, n), jnp.bfloat16)}
        d = np.asarray(tree_combine(tree, c)["l"], np.float32)
        want = float(np.sum(1.0 + offs))         # = W exactly (symmetric)
        np.testing.assert_allclose(d, want, rtol=1e-2)
        # the truncated-weights bug collapses every offset to 0 or +-eps;
        # detect survival of the sub-bf16 structure through a non-uniform
        # leaf in fp32, where the comparison is exact:
        rng = np.random.default_rng(67)
        leaf = jnp.asarray(rng.normal(size=(W, n)), jnp.float32)
        got = np.asarray(tree_combine({"l": leaf}, c)["l"])
        ref = np.asarray(leaf).T @ (1.0 + offs)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)

    def test_output_dtype_matches_leaf(self):
        c = jnp.asarray(np.ones(4, np.float32))
        tree = {"l": jnp.ones((4, 16), jnp.bfloat16)}
        assert tree_combine(tree, c)["l"].dtype == jnp.bfloat16
