"""HLO collective parser tests: scanned == unrolled after loop correction."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

# The parser must run against HLO produced with multiple host devices; spawn
# a subprocess so XLA_FLAGS apply before jax init.
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.hlo import parse_collectives

    mesh = jax.make_mesh((4,), ("model",))
    W_SH = NamedSharding(mesh, P(None, "model"))
    R_SH = NamedSharding(mesh, P(None, None))

    def layer(x, w):
        y = jax.lax.with_sharding_constraint(x @ w, W_SH)
        return jax.lax.with_sharding_constraint(y @ w.T, R_SH)

    def scanned(x, ws):
        def body(c, w):
            return layer(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x = layer(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    out = {}
    with mesh:
        for name, fn in [("scanned", scanned), ("unrolled", unrolled)]:
            c = jax.jit(fn, in_shardings=(R_SH, None)).lower(x, ws).compile()
            st = parse_collectives(c.as_text(), 4)
            out[name] = {"total": st.total_moved_bytes,
                         "kinds": st.per_kind_bytes,
                         "loops": st.loop_multipliers}
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def hlo_results():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-2000:]
    import json
    return json.loads(res.stdout.strip().splitlines()[-1])


class TestLoopCorrection:
    def test_scanned_matches_unrolled(self, hlo_results):
        s, u = hlo_results["scanned"], hlo_results["unrolled"]
        assert u["total"] > 0
        np.testing.assert_allclose(s["total"], u["total"], rtol=0.05)

    def test_trip_count_detected(self, hlo_results):
        loops = hlo_results["scanned"]["loops"]
        assert any(int(v) == 6 for v in loops.values()), loops

    def test_allreduce_volume_sane(self, hlo_results):
        # per layer: one AR of f32[64,64] = 16384B * 2*(3/4) = 24576B; 6 layers
        ar = hlo_results["unrolled"]["kinds"].get("all-reduce", 0)
        np.testing.assert_allclose(ar, 6 * 16384 * 1.5, rtol=0.05)
