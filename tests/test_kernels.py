"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Marked ``slow``: Pallas interpret mode is minutes-scale on CPU, so CI runs
this module in a separate non-blocking lane (the <2 min gating lane
deselects it with ``-m "not slow"``); the tier-1 command still runs it
locally."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.kernels.coord_stats import ref as cs_ref
from repro.kernels.coord_stats.kernel import coord_stats_pallas
from repro.kernels.flash_attn.kernel import flash_attn_pallas
from repro.kernels.flash_attn.ref import flash_attn_ref
from repro.kernels.gram.kernel import gram_pallas, tree_gram_pallas
from repro.kernels.gram.ref import gram_ref, tree_gram_chunk_ref
from repro.kernels.weighted_sum.kernel import weighted_sum_pallas
from repro.kernels.weighted_sum.ref import weighted_sum_ref

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype=dtype)


class TestGramKernel:
    @pytest.mark.parametrize("n,p", [(64, 3), (1000, 15), (4096, 16),
                                     (777, 32), (2048, 60)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, rng, n, p, dtype):
        G = _rand(rng, (n, p), dtype)
        got = gram_pallas(G, block_n=256, interpret=True)
        want = gram_ref(G)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                                   atol=2e-1 if dtype == jnp.bfloat16 else 1e-2)

    def test_block_size_invariance(self, rng):
        G = _rand(rng, (1500, 12), jnp.float32)
        a = gram_pallas(G, block_n=128, interpret=True)
        b = gram_pallas(G, block_n=512, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    def test_symmetry_and_psd(self, rng):
        G = _rand(rng, (512, 10), jnp.float32)
        K = np.asarray(gram_pallas(G, interpret=True))
        np.testing.assert_allclose(K, K.T, rtol=1e-5)
        assert np.linalg.eigvalsh(K).min() > -1e-3


class TestFusedTreeGramKernel:
    """The one-pass chunk-streamed tree Gram vs its jnp chunk oracle.

    Uses module-local generators (not the shared session ``rng``) so the
    pre-existing kernel sweeps keep their exact random streams."""

    @pytest.mark.parametrize("w,n", [(3, 700), (7, 2048), (16, 5000),
                                     (32, 1111)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_chunk_ref(self, w, n, dtype):
        rng = np.random.default_rng(w * 10_000 + n)
        X = _rand(rng, (w, n), dtype)
        got = tree_gram_pallas(X, block_n=256, interpret=True)
        want = tree_gram_chunk_ref(X, block_n=256)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want),
            rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
            atol=2e-1 if dtype == jnp.bfloat16 else 1e-2)

    @pytest.mark.parametrize("stride", [2, 4])
    def test_sketch_stride_matches_chunk_ref(self, stride):
        """Index-map chunk sampling == the jnp chunk subset, bit-for-bit
        plan: both sides consume the same chunk_schedule."""
        rng = np.random.default_rng(71 + stride)
        X = _rand(rng, (5, 9000), jnp.float32)
        got = tree_gram_pallas(X, sketch_stride=stride, block_n=512,
                               interpret=True)
        want = tree_gram_chunk_ref(X, sketch_stride=stride, block_n=512)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)

    def test_block_size_invariance_unsketched(self):
        rng = np.random.default_rng(73)
        X = _rand(rng, (6, 3000), jnp.float32)
        a = tree_gram_pallas(X, block_n=128, interpret=True)
        b = tree_gram_pallas(X, block_n=1024, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-3)

    def test_symmetry_and_psd(self):
        rng = np.random.default_rng(79)
        X = _rand(rng, (10, 1500), jnp.float32)
        K = np.asarray(tree_gram_pallas(X, interpret=True))
        np.testing.assert_allclose(K, K.T, rtol=1e-5)
        assert np.linalg.eigvalsh(K).min() > -1e-3


class TestWeightedSumKernel:
    @pytest.mark.parametrize("n,p", [(64, 3), (1000, 15), (4096, 32), (513, 7)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, rng, n, p, dtype):
        G = _rand(rng, (n, p), dtype)
        c = _rand(rng, (p,), jnp.float32)
        got = weighted_sum_pallas(G, c, block_n=256, interpret=True)
        want = weighted_sum_ref(G, c)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5,
            atol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


class TestCoordStatsKernel:
    @pytest.mark.parametrize("op", ["median", "trimmed_mean", "meamed", "phocas"])
    @pytest.mark.parametrize("p,n,f", [(5, 300, 1), (15, 1000, 3),
                                       (16, 512, 2), (8, 257, 1)])
    def test_matches_ref(self, rng, op, p, n, f):
        Gw = _rand(rng, (p, n), jnp.float32)
        got = coord_stats_pallas(Gw, op=op, f=f, block_n=256, interpret=True)
        want = {"median": lambda: cs_ref.median_ref(Gw),
                "trimmed_mean": lambda: cs_ref.trimmed_mean_ref(Gw, f),
                "meamed": lambda: cs_ref.meamed_ref(Gw, f),
                "phocas": lambda: cs_ref.phocas_ref(Gw, f)}[op]()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=f"{op}")

    def test_even_p_median(self, rng):
        Gw = _rand(rng, (6, 100), jnp.float32)
        got = coord_stats_pallas(Gw, op="median", interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.median(np.asarray(Gw), axis=0), rtol=1e-5)


class TestFlashAttnKernel:
    @pytest.mark.parametrize("b,h,sq,sk,d", [
        (1, 2, 128, 128, 64),     # square prefill
        (2, 1, 64, 64, 128),
        (1, 2, 1, 256, 64),       # decode: one query, long cache
        (1, 1, 100, 100, 64),     # non-multiple of block
        (1, 1, 37, 256, 64),      # chunked prefill tail
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, rng, b, h, sq, sk, d, dtype):
        q = _rand(rng, (b, h, sq, d), dtype)
        k = _rand(rng, (b, h, sk, d), dtype)
        v = _rand(rng, (b, h, sk, d), dtype)
        got = flash_attn_pallas(q, k, v, causal=True, block_q=32, block_k=32,
                                interpret=True)
        want = flash_attn_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=4e-2 if dtype == jnp.bfloat16 else 2e-4,
            atol=4e-2 if dtype == jnp.bfloat16 else 2e-4)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, rng, window):
        q = _rand(rng, (1, 2, 128, 64), jnp.float32)
        k = _rand(rng, (1, 2, 128, 64), jnp.float32)
        v = _rand(rng, (1, 2, 128, 64), jnp.float32)
        got = flash_attn_pallas(q, k, v, causal=True, window=window,
                                block_q=32, block_k=32, interpret=True)
        want = flash_attn_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_non_causal(self, rng):
        q = _rand(rng, (1, 1, 64, 64), jnp.float32)
        k = _rand(rng, (1, 1, 96, 64), jnp.float32)
        v = _rand(rng, (1, 1, 96, 64), jnp.float32)
        got = flash_attn_pallas(q, k, v, causal=False, block_q=32, block_k=32,
                                interpret=True)
        want = flash_attn_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_rows_sum_to_attention_of_ones(self, rng):
        """Value = ones => output rows must be exactly ones (softmax sums 1)."""
        q = _rand(rng, (1, 1, 64, 32), jnp.float32)
        k = _rand(rng, (1, 1, 64, 32), jnp.float32)
        v = jnp.ones((1, 1, 64, 32), jnp.float32)
        got = flash_attn_pallas(q, k, v, causal=True, block_q=16, block_k=16,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(got), 1.0, rtol=1e-5)
