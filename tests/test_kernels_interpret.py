"""Tier-1 interpret-mode execution smoke for the production kernels.

One *tiny-shape* run per kernel family under the Pallas interpreter —
the dynamic twin of the static KTILING rule: an index map that reads out
of bounds at runtime fails here even if a rule regression ever let it
through statically.  The exhaustive allclose sweeps stay in the slow
lane (``tests/test_kernels.py``); these shapes are chosen to trace and
run in seconds so tier-1 always executes every kernel at least once.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coord_stats.kernel import (bulyan_select_pallas,
                                              coord_stats_pallas,
                                              krum_scores_pallas)
from repro.kernels.coord_stats.ref import median_ref
from repro.kernels.flash_attn.kernel import flash_attn_pallas
from repro.kernels.flash_attn.ref import flash_attn_ref
from repro.kernels.gram.kernel import gram_pallas, tree_gram_pallas
from repro.kernels.gram.ref import gram_ref
from repro.kernels.weighted_sum.kernel import weighted_sum_pallas
from repro.kernels.weighted_sum.ref import weighted_sum_ref


@pytest.fixture(scope="module")
def prng():
    return np.random.default_rng(11)


def test_gram_interpret(prng):
    G = jnp.asarray(prng.normal(size=(300, 6)), jnp.float32)
    got = gram_pallas(G, block_n=128, interpret=True)
    np.testing.assert_allclose(got, gram_ref(G), rtol=1e-5, atol=1e-5)


def test_tree_gram_interpret(prng):
    X = jnp.asarray(prng.normal(size=(6, 700)), jnp.float32)
    got = tree_gram_pallas(X, block_n=256, interpret=True)
    np.testing.assert_allclose(got, X @ X.T, rtol=1e-5, atol=1e-5)


def test_coord_stats_interpret(prng):
    Gw = jnp.asarray(prng.normal(size=(7, 500)), jnp.float32)
    got = coord_stats_pallas(Gw, op="median", f=1, block_n=256,
                             interpret=True)
    np.testing.assert_allclose(got, median_ref(Gw), rtol=1e-6, atol=1e-6)


def test_coord_stats_masked_interpret(prng):
    Gw = jnp.asarray(prng.normal(size=(7, 300)), jnp.float32)
    mask = jnp.asarray([1, 1, 0, 1, 1, 0, 1], jnp.float32)
    got = coord_stats_pallas(Gw, mask, op="median", f=1, block_n=256,
                             interpret=True)
    ref = median_ref(Gw[jnp.asarray([0, 1, 3, 4, 6])])
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)


def test_krum_bulyan_interpret(prng):
    G = prng.normal(size=(9, 40))
    D2 = jnp.asarray(
        ((G[:, None, :] - G[None, :, :]) ** 2).sum(-1), jnp.float32)
    scores = krum_scores_pallas(D2, f=2, interpret=True)
    # reference: sum of the p-f-2 smallest off-diagonal distances per row
    k = 9 - 2 - 2
    srt = np.sort(np.asarray(D2) + np.diag([np.inf] * 9), axis=1)
    np.testing.assert_allclose(scores, srt[:, :k].sum(1), rtol=1e-5)
    picks = bulyan_select_pallas(D2, f=2, interpret=True)
    assert picks.shape == (max(9 - 4, 1),)
    assert len(set(np.asarray(picks).tolist())) == picks.shape[0]


def test_flash_attn_interpret(prng):
    q = jnp.asarray(prng.normal(size=(1, 2, 24, 16)), jnp.float32)
    k = jnp.asarray(prng.normal(size=(1, 2, 40, 16)), jnp.float32)
    v = jnp.asarray(prng.normal(size=(1, 2, 40, 16)), jnp.float32)
    got = flash_attn_pallas(q, k, v, causal=True, block_q=8, block_k=16,
                            interpret=True)
    ref = flash_attn_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_flash_attn_decode_bf16_interpret(prng):
    q = jnp.asarray(prng.normal(size=(2, 2, 1, 16)), jnp.bfloat16)
    k = jnp.asarray(prng.normal(size=(2, 2, 48, 16)), jnp.bfloat16)
    v = jnp.asarray(prng.normal(size=(2, 2, 48, 16)), jnp.bfloat16)
    got = flash_attn_pallas(q, k, v, causal=False, block_q=8, block_k=16,
                            interpret=True)
    assert got.dtype == jnp.bfloat16          # fp32 accumulator, cast out
    ref = flash_attn_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=False)
    np.testing.assert_allclose(got.astype(jnp.float32), ref,
                               rtol=2e-2, atol=2e-2)


def test_weighted_sum_interpret(prng):
    G = jnp.asarray(prng.normal(size=(500, 6)), jnp.float32)
    c = jnp.asarray(prng.normal(size=(6,)), jnp.float32)
    got = weighted_sum_pallas(G, c, block_n=256, interpret=True)
    np.testing.assert_allclose(got, weighted_sum_ref(G, c),
                               rtol=1e-5, atol=1e-5)
