"""Membership layer: fault schedules, masked aggregation == subset
aggregation for all 11 rules, in-graph churn without recompiles, EF
freezing across membership changes.

Local rngs throughout (the shared session-scoped fixture makes
statistical tolerances order-dependent).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import assert_no_recompile
from repro.comm import CommConfig, init_ef
from repro.configs import get_config, reduce_for_smoke
from repro.core import FlagConfig
from repro.core.gram import fa_weights_from_gram, gram_matrix
from repro.dist.aggregation import (AggregatorConfig, aggregate_tree,
                                    compressed_aggregate)
from repro.dist.membership import (FaultEvent, FaultSchedule,
                                   get_fault_schedule, membership_at)
from repro.dist.train_step import (TrainConfig, build_train_step,
                                   init_train_state)
from repro.optim import constant, sgd

ALL_RULES = ["mean", "flag", "pca", "median", "trimmed_mean", "meamed",
             "phocas", "krum", "multi_krum", "bulyan", "geomed"]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

class TestFaultSchedules:
    def test_trivial(self):
        mem = membership_at(FaultSchedule(), 5, 4)
        assert bool(jnp.all(mem.active))
        assert bool(jnp.all(mem.staleness == 0))

    def test_crash_is_permanent(self):
        s = get_fault_schedule("crash", 6, n=2, at=4)
        for t, expect_active in [(3, 6), (4, 4), (1000, 4)]:
            mem = membership_at(s, t, 6)
            assert int(jnp.sum(mem.active)) == expect_active
        # the last n workers crash (disjoint from the first-f Byzantine set)
        mem = membership_at(s, 10, 6)
        assert not bool(mem.active[5]) and not bool(mem.active[4])
        assert bool(mem.active[0])

    def test_rejoin_interval_and_staleness(self):
        s = get_fault_schedule("rejoin", 4, n=1, at=3, down=4)
        assert bool(membership_at(s, 2, 4).active[3])
        for t in range(3, 7):
            mem = membership_at(s, t, 4)
            assert not bool(mem.active[3])
            assert int(mem.staleness[3]) == t - 3 + 1
        mem = membership_at(s, 7, 4)
        assert bool(mem.active[3]) and int(mem.staleness[3]) == 0

    def test_churn_rotates(self):
        s = get_fault_schedule("churn", 3, period=2, horizon=12)
        outs = [int(jnp.argmin(membership_at(s, t, 3).active))
                for t in (0, 2, 4, 6)]
        assert outs == [0, 1, 2, 0]
        assert all(int(jnp.sum(membership_at(s, t, 3).active)) == 2
                   for t in range(8))

    def test_straggle_periodic(self):
        s = get_fault_schedule("straggle", 5, n=1, every=4, duration=2,
                               horizon=20)
        drops = [t for t in range(20)
                 if not bool(membership_at(s, t, 5).active[4])]
        assert drops == [4, 5, 8, 9, 12, 13, 16, 17]

    def test_schedule_validation(self):
        with pytest.raises(ValueError):
            FaultEvent("crash", 0, 5, 3)
        with pytest.raises(ValueError):
            FaultEvent("explode", 0, 5)
        with pytest.raises(ValueError):
            membership_at(FaultSchedule((FaultEvent("crash", 9, 0),)), 0, 4)
        with pytest.raises(KeyError):
            get_fault_schedule("nope", 4)

    def test_membership_is_jit_pure(self):
        s = get_fault_schedule("churn", 4, period=3, horizon=30)
        f = jax.jit(lambda t: membership_at(s, t, 4))
        masks = {np.asarray(f(t).active).tobytes() for t in range(9)}
        assert len(masks) > 1
        assert_no_recompile(f, name="membership_at")  # RECOMPILE rule


# ---------------------------------------------------------------------------
# masked aggregation == aggregation on the active subset
# ---------------------------------------------------------------------------

def _worker_tree(seed, W):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(W, 8, 6)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(W, 30)), jnp.float32)}}
    # give workers distinct scales so selection rules have real choices
    tree = jax.tree.map(
        lambda l: l * jnp.linspace(0.5, 2.0, W).reshape(
            (W,) + (1,) * (l.ndim - 1)), tree)
    return tree


ACTIVE = np.array([1, 0, 1, 1, 0, 1, 1, 0, 1], bool)   # non-contiguous


@pytest.mark.parametrize("name", ALL_RULES)
class TestMaskedEqualsSubset:
    def test_equivalence(self, name):
        W = ACTIVE.size
        tree = _worker_tree(3, W)
        sub = jax.tree.map(lambda l: l[ACTIVE], tree)
        mask = jnp.asarray(ACTIVE, jnp.float32)
        # explicit m + tol=0: both runs execute the same IRLS iteration
        # count, so the comparison is numerics-only (see gram.py)
        cfg = AggregatorConfig(name=name, f=1,
                               flag=FlagConfig(lam=2.0, m=3, tol=0.0))
        d_m, aux_m = aggregate_tree(tree, cfg, mask=mask)
        d_s, _ = aggregate_tree(sub, cfg)
        for a, b in zip(jax.tree.leaves(d_m), jax.tree.leaves(d_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)

    def test_inactive_weights_are_zero(self, name):
        W = ACTIVE.size
        tree = _worker_tree(4, W)
        cfg = AggregatorConfig(name=name, f=1,
                               flag=FlagConfig(lam=2.0, m=3))
        _, aux = aggregate_tree(tree, cfg,
                                mask=jnp.asarray(ACTIVE, jnp.float32))
        w = np.asarray(aux["weights"])
        assert np.all(w[~ACTIVE] == 0.0)
        assert np.abs(w[ACTIVE]).sum() > 0

    def test_inactive_values_cannot_leak(self, name):
        """Poisoning an inactive worker's slot with huge garbage changes
        nothing — the definition of being out of the round."""
        W = ACTIVE.size
        tree = _worker_tree(5, W)
        mask = jnp.asarray(ACTIVE, jnp.float32)
        cfg = AggregatorConfig(name=name, f=1,
                               flag=FlagConfig(lam=2.0, m=3, tol=0.0))
        d0, _ = aggregate_tree(tree, cfg, mask=mask)
        idx = int(np.flatnonzero(~ACTIVE)[0])
        poisoned = jax.tree.map(
            lambda l: l.at[idx].set(1e6 * jnp.ones_like(l[idx])), tree)
        d1, _ = aggregate_tree(poisoned, cfg, mask=mask)
        for a, b in zip(jax.tree.leaves(d0), jax.tree.leaves(d1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


def test_adjacent_events_merge_for_staleness():
    """Two back-to-back outage intervals are one consecutive absence."""
    s = FaultSchedule((FaultEvent("leave", 0, 0, 5),
                       FaultEvent("leave", 0, 5, 10)))
    mem = membership_at(s, 7, 2)
    assert not bool(mem.active[0])
    assert int(mem.staleness[0]) == 8         # out since step 0, inclusive
    assert bool(membership_at(s, 10, 2).active[0])


@pytest.mark.parametrize("name", ["krum", "multi_krum", "bulyan"])
def test_degenerate_quorum_never_selects_inactive(name):
    """With <= 1 active worker the selection rules must still put zero
    weight on every inactive worker (a lone active worker has no peers to
    score against; its +inf score must not hand the pick to a departed
    worker's garbage slot)."""
    W = 4
    tree = _worker_tree(9, W)
    cfg = AggregatorConfig(name=name, f=0)
    for active in ([0, 0, 0, 1], [0, 0, 0, 0]):
        mask = jnp.asarray(active, jnp.float32)
        d, aux = aggregate_tree(tree, cfg, mask=mask)
        w = np.asarray(aux["weights"])
        assert np.all(w[~np.asarray(active, bool)] == 0.0), (name, active, w)
        if sum(active) == 1 and name != "bulyan":
            # the lone active worker IS the aggregate
            lone = int(np.argmax(active))
            for out, leaf in zip(jax.tree.leaves(d), jax.tree.leaves(tree)):
                np.testing.assert_allclose(np.asarray(out),
                                           np.asarray(leaf[lone]),
                                           rtol=1e-5, atol=1e-6)


class TestGeomedDegenerateMembership:
    """Weiszfeld with <= 1 active worker must be exact and finite by
    construction — not via the eps distance clip or the 1e-30 sum clamp
    (see _geomed_weights)."""

    def _gram(self, W=5, seed=31):
        rng = np.random.default_rng(seed)
        G = jnp.asarray(rng.normal(size=(60, W)), jnp.float32)
        return gram_matrix(G)

    @pytest.mark.parametrize("eps", [1e-8, 0.0])
    def test_single_active_exact_one_hot_under_jit(self, eps):
        from repro.dist.aggregation import _geomed_weights
        K = self._gram()
        fn = jax.jit(lambda k, m: _geomed_weights(k, eps=eps, mask=m))
        for idx in range(K.shape[0]):
            mask = jnp.zeros((K.shape[0],), jnp.float32).at[idx].set(1.0)
            w = np.asarray(fn(K, mask))
            assert np.all(np.isfinite(w)), (idx, eps, w)
            want = np.zeros(K.shape[0], np.float32)
            want[idx] = 1.0
            np.testing.assert_array_equal(w, want)

    def test_zero_active_is_zero_not_nan(self):
        from repro.dist.aggregation import _geomed_weights
        K = self._gram(seed=32)
        w = np.asarray(jax.jit(
            lambda k, m: _geomed_weights(k, mask=m))(
                K, jnp.zeros((K.shape[0],), jnp.float32)))
        assert np.all(np.isfinite(w))
        np.testing.assert_array_equal(w, np.zeros_like(w))

    def test_aggregate_tree_geomed_single_active(self):
        """Through the full jit'd aggregation path: the lone active
        worker IS the aggregate, bitwise, and its weight is exactly 1."""
        W = 4
        tree = _worker_tree(33, W)
        mask = jnp.zeros((W,), jnp.float32).at[2].set(1.0)
        step = jax.jit(lambda t, m: aggregate_tree(
            t, AggregatorConfig(name="geomed"), mask=m))
        d, aux = step(tree, mask)
        w = np.asarray(aux["weights"])
        want = np.zeros(W, np.float32)
        want[2] = 1.0
        np.testing.assert_array_equal(w, want)
        for out, leaf in zip(jax.tree.leaves(d), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(leaf[2]))


def test_masked_fa_solver_agreement():
    """rank_p and qspace oracles agree on masked problems too."""
    rng = np.random.default_rng(7)
    W = ACTIVE.size
    G = jnp.asarray(rng.normal(size=(200, W)), jnp.float32)
    K = gram_matrix(G)
    mask = jnp.asarray(ACTIVE, jnp.float32)
    cfg = FlagConfig(lam=2.0, m=3, tol=0.0)
    c_r, _ = fa_weights_from_gram(K, cfg, solver="rank_p", mask=mask)
    c_q, _ = fa_weights_from_gram(K, cfg, solver="qspace", mask=mask)
    np.testing.assert_allclose(np.asarray(c_r), np.asarray(c_q),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# EF + comm under membership
# ---------------------------------------------------------------------------

class TestMembershipComm:
    def test_ef_frozen_for_inactive(self):
        W = ACTIVE.size
        tree = _worker_tree(6, W)
        params = jax.tree.map(lambda l: l[0], tree)
        ef = jax.tree.map(lambda l: l + 1.0, init_ef(params, W))
        comm = CommConfig(codec="signsgd")
        mask = jnp.asarray(ACTIVE, jnp.float32)
        _, _, new_ef = compressed_aggregate(
            tree, AggregatorConfig(name="mean"), comm, ef, mask=mask)
        for n, o in zip(jax.tree.leaves(new_ef), jax.tree.leaves(ef)):
            np.testing.assert_array_equal(np.asarray(n[~ACTIVE]),
                                          np.asarray(o[~ACTIVE]))
            assert bool(jnp.any(n[ACTIVE] != o[ACTIVE]))

    def test_comm_bits_scale_with_active_fraction(self):
        W = ACTIVE.size
        tree = _worker_tree(8, W)
        cfg = AggregatorConfig(name="mean")
        _, aux_full, _ = compressed_aggregate(tree, cfg)
        _, aux_m, _ = compressed_aggregate(
            tree, cfg, mask=jnp.asarray(ACTIVE, jnp.float32))
        frac = ACTIVE.sum() / W
        np.testing.assert_allclose(float(aux_m["comm_bits"]),
                                   float(aux_full["comm_bits"]) * frac,
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end: churn through the train step, one compile
# ---------------------------------------------------------------------------

class TestTrainStepChurn:
    def test_churn_no_recompile_and_masked_weights(self):
        cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0)
        W = 6
        sched = get_fault_schedule("churn", W, period=2, horizon=32)
        tc = TrainConfig(
            aggregator=AggregatorConfig(
                name="flag", flag=FlagConfig(lam=0.0, regularizer="none")),
            faults=sched)
        opt = sgd(momentum=0.9)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step_fn = jax.jit(build_train_step(cfg, tc, opt, constant(1e-3)))

        rng = np.random.default_rng(11)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (W, 2, 16)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (W, 2, 16)), jnp.int32),
        }
        out_worker = []     # which worker the *step's own metrics* say is out
        for t in range(6):
            params, opt_state, m = step_fn(params, opt_state, batch,
                                           jax.random.PRNGKey(t),
                                           jnp.asarray(t, jnp.int32))
            assert bool(jnp.isfinite(m["loss"]))
            mem = membership_at(sched, t, W)
            assert int(m["active_workers"]) == int(jnp.sum(mem.active))
            assert int(m["active_workers"]) == W - 1
            w = np.asarray(m["fa_weights"])
            inactive = ~np.asarray(mem.active)
            assert np.all(w[inactive] == 0.0)
            np.testing.assert_array_equal(np.asarray(m["worker_staleness"]),
                                          np.asarray(mem.staleness))
            # the compiled step tracked the traced step index, not a baked
            # step-0 mask: the out worker (stale, zero-weight) rotates
            out_worker.append(int(np.argmax(
                np.asarray(m["worker_staleness"]) > 0)))
        assert len(set(out_worker)) > 1, out_worker
        # ...and membership changed across the run on ONE compilation
        assert_no_recompile(step_fn, name="train_step/churn")

    def test_trivial_schedule_has_no_membership_metrics(self):
        cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
            frontend=None, num_prefix_embeds=0)
        opt = sgd(momentum=0.9)
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step_fn = jax.jit(build_train_step(cfg, TrainConfig(), opt,
                                           constant(1e-3)))
        rng = np.random.default_rng(12)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (4, 2, 16)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (4, 2, 16)), jnp.int32),
        }
        *_, m = step_fn(params, opt_state, batch, jax.random.PRNGKey(0),
                        jnp.zeros((), jnp.int32))
        assert "active_workers" not in m and "worker_staleness" not in m
