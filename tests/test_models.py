"""Model-substrate tests: cells, blocks, decode-vs-prefill consistency."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduce_for_smoke
from repro.models import layers, rglru as rglru_lib, ssm, transformer


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


class TestMLSTM:
    def test_chunkwise_matches_sequential(self, rng):
        B, H, S, dk = 2, 3, 64, 16
        q = _rand(rng, (B, H, S, dk))
        k = _rand(rng, (B, H, S, dk)) * dk ** -0.5
        v = _rand(rng, (B, H, S, dk))
        li = _rand(rng, (B, H, S)) * 0.5
        lf = jax.nn.log_sigmoid(_rand(rng, (B, H, S)) + 2.0)
        st = ssm.mlstm_state_init(B, H, dk, dk)
        h_seq, st_seq = ssm.mlstm_sequential(q, k, v, li, lf, st)
        for chunk in (8, 16, 64, 256):
            h_chk, st_chk = ssm.mlstm_parallel(q, k, v, li, lf, st, chunk=chunk)
            np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq),
                                       rtol=2e-4, atol=2e-4,
                                       err_msg=f"chunk={chunk}")
            for a, b in zip(st_chk[:2], st_seq[:2]):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-3, atol=2e-3)

    def test_state_carry_equals_full_sequence(self, rng):
        """Processing [first half; second half] with carried state == full."""
        B, H, S, dk = 1, 2, 32, 8
        args = [_rand(rng, (B, H, S, dk)) for _ in range(3)]
        li = _rand(rng, (B, H, S)) * 0.3
        lf = jax.nn.log_sigmoid(_rand(rng, (B, H, S)) + 2.0)
        st0 = ssm.mlstm_state_init(B, H, dk, dk)
        h_full, _ = ssm.mlstm_parallel(*args, li, lf, st0, chunk=8)
        half = S // 2
        cut4 = lambda t: (t[:, :, :half], t[:, :, half:])
        (q1, q2), (k1, k2), (v1, v2) = map(cut4, args)
        (l1, l2), (f1, f2) = cut4(li), cut4(lf)
        h1, st1 = ssm.mlstm_parallel(q1, k1, v1, l1, f1, st0, chunk=8)
        h2, _ = ssm.mlstm_parallel(q2, k2, v2, l2, f2, st1, chunk=8)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 2)),
                                   np.asarray(h_full), rtol=2e-4, atol=2e-4)


class TestRGLRU:
    def test_matches_sequential(self, rng):
        B, S, d = 2, 24, 16
        p = rglru_lib.rglru_init(jax.random.PRNGKey(1), d)
        x = _rand(rng, (B, S, d))
        y, h_last = rglru_lib.rglru_apply(p, x)
        # sequential oracle
        r = jax.nn.sigmoid(layers.linear(p["wr"], x, jnp.float32))
        i = jax.nn.sigmoid(layers.linear(p["wi"], x, jnp.float32))
        a = jnp.exp(-8.0 * jax.nn.softplus(p["lam"]) * r)
        b = jnp.sqrt(jnp.clip(1 - a * a, 1e-12)) * (i * x)
        h = jnp.zeros((B, d))
        outs = []
        for t in range(S):
            h = a[:, t] * h + b[:, t]
            outs.append(h)
        want = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(want[:, -1]),
                                   rtol=1e-5, atol=1e-5)

    def test_state_carry(self, rng):
        B, S, d = 1, 16, 8
        p = rglru_lib.rglru_init(jax.random.PRNGKey(2), d)
        x = _rand(rng, (B, S, d))
        y_full, _ = rglru_lib.rglru_apply(p, x)
        y1, h1 = rglru_lib.rglru_apply(p, x[:, :8])
        y2, _ = rglru_lib.rglru_apply(p, x[:, 8:], h1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-5, atol=1e-5)


class TestConv:
    def test_causal_and_state(self, rng):
        p = ssm.conv_init(jax.random.PRNGKey(0), 4, 8)
        x = _rand(rng, (2, 20, 8))
        y_full, _ = ssm.conv_apply(p, x)
        y1, st = ssm.conv_apply(p, x[:, :9])
        y2, _ = ssm.conv_apply(p, x[:, 9:], st)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-5, atol=1e-5)
        # causality: perturbing x_t must not change y_{<t}
        x2 = x.at[:, 10].add(100.0)
        y_pert, _ = ssm.conv_apply(p, x2)
        np.testing.assert_allclose(np.asarray(y_pert[:, :10]),
                                   np.asarray(y_full[:, :10]), rtol=1e-5)


class TestRoPE:
    def test_norm_preserved(self, rng):
        x = _rand(rng, (2, 4, 16, 64))
        pos = jnp.broadcast_to(jnp.arange(16)[None, None], (2, 4, 16))
        y = layers.apply_rope(x, pos)
        np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                                   np.asarray(jnp.linalg.norm(x, axis=-1)),
                                   rtol=1e-4)

    def test_relative_property(self, rng):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        q = _rand(rng, (1, 1, 1, 32))
        k = _rand(rng, (1, 1, 1, 32))
        def dot(m, n):
            qm = layers.apply_rope(q, jnp.full((1, 1, 1), m))
            kn = layers.apply_rope(k, jnp.full((1, 1, 1), n))
            return float(jnp.sum(qm * kn))
        assert abs(dot(5, 3) - dot(10, 8)) < 1e-4

    def test_partial_fraction(self, rng):
        x = _rand(rng, (1, 1, 4, 64))
        pos = jnp.broadcast_to(jnp.arange(4)[None, None], (1, 1, 4))
        y = layers.apply_rope(x, pos, rope_fraction=0.25)
        np.testing.assert_array_equal(np.asarray(y[..., 16:]),
                                      np.asarray(x[..., 16:]))


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestDecodeConsistency:
    """Token-by-token decode must match the parallel prefill forward."""

    def test_decode_matches_prefill(self, rng, arch):
        cfg = reduce_for_smoke(get_config(arch))
        cfg = cfg.replace(frontend=None, num_prefix_embeds=0)  # token path
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        B, S = 1, 12
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        logits_par = transformer.prefill(params, {"tokens": toks}, cfg)

        caches = transformer.init_caches(cfg, B, max_len=S, dtype=jnp.float32)
        outs = []
        for t in range(S):
            lg, caches = transformer.decode_step(
                params, toks[:, t:t + 1], caches, jnp.asarray(t, jnp.int32),
                cfg, max_len=S)
            outs.append(lg[:, 0])
        logits_seq = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(logits_seq),
                                   np.asarray(logits_par),
                                   rtol=2e-2, atol=2e-2, err_msg=arch)


class TestParamCount:
    def test_full_config_counts(self):
        """Full configs land near their nameplate sizes."""
        expect = {
            "xlstm-1.3b": (1.1e9, 1.8e9),
            "smollm-360m": (0.30e9, 0.45e9),
            "mixtral-8x7b": (44e9, 50e9),
            "starcoder2-15b": (14e9, 17e9),
            "stablelm-1.6b": (1.4e9, 1.9e9),
            # 30.3B is exact for the assigned spec (40L, d8192, d_ff 22528,
            # 256k tied vocab); the "35b" nameplate includes nothing we omit
            # beyond spec.
            "command-r-35b": (29e9, 38e9),
            "deepseek-moe-16b": (15e9, 19e9),
            "musicgen-medium": (1.3e9, 2.2e9),
            "recurrentgemma-9b": (7.5e9, 10.5e9),
            "phi-3-vision-4.2b": (3.6e9, 4.5e9),
        }
        for name, (lo, hi) in expect.items():
            n = get_config(name).param_count()
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"

    def test_moe_active_params(self):
        cfg = get_config("mixtral-8x7b")
        active = cfg.active_param_count()
        total = cfg.param_count()
        assert active < 0.4 * total          # 2/8 experts + attention
        assert 10e9 < active < 16e9          # ~12.9B nameplate active
