"""MoE dispatch unit tests (routing, capacity dropping, shared experts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_lib
from repro.models.config import ModelConfig, MoESettings


def _cfg(E=4, k=2, shared=0, cf=4.0):
    return ModelConfig(
        name="t", arch_type="moe", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64,
        moe=MoESettings(num_experts=E, top_k=k, num_shared=shared,
                        d_expert=64, capacity_factor=cf),
        compute_dtype="float32")


class TestMoE:
    def test_output_shape_and_finite(self, rng):
        cfg = _cfg()
        p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
        y, losses = moe_lib.moe_apply(p, x, cfg)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))
        assert set(losses) == {"moe_aux", "moe_z"}

    def test_matches_dense_oracle_when_dropfree(self, rng):
        """With capacity >= T, output == explicit per-token expert mix."""
        cfg = _cfg(cf=8.0)
        p = moe_lib.moe_init(jax.random.PRNGKey(1), cfg)
        x = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
        y, _ = moe_lib.moe_apply(p, x, cfg)

        # oracle: run every expert densely, combine with router weights
        xt = x.reshape(8, 32)
        logits = xt @ p["router"]["w"]
        probs = jax.nn.softmax(logits, -1)
        top_p, top_e = jax.lax.top_k(probs, 2)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        dense = moe_lib._expert_ffn(p["w_up"], p["w_gate"], p["w_down"],
                                    jnp.broadcast_to(xt[None], (4, 8, 32)),
                                    cfg)                     # (E, T, d)
        want = jnp.zeros_like(xt)
        for t in range(8):
            for j in range(2):
                want = want.at[t].add(top_p[t, j] * dense[top_e[t, j], t])
        np.testing.assert_allclose(np.asarray(y.reshape(8, 32)),
                                   np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_capacity_drops_excess(self, rng):
        """All tokens routed to one expert + tiny capacity => most dropped."""
        cfg = _cfg(E=4, k=1, cf=0.26)
        p = moe_lib.moe_init(jax.random.PRNGKey(2), cfg)
        # identical tokens -> identical routing -> one expert overloaded
        x = jnp.ones((1, 64, 32), jnp.float32)
        y, _ = moe_lib.moe_apply(p, x, cfg)
        # capacity = max(8, 64*1/4*0.26~=5) = 8 of 64 tokens survive
        nz = jnp.sum(jnp.any(jnp.abs(y[0]) > 0, axis=-1))
        assert int(nz) == 8

    def test_shared_experts_always_on(self, rng):
        cfg = _cfg(shared=2)
        p = moe_lib.moe_init(jax.random.PRNGKey(3), cfg)
        x = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
        y_with, _ = moe_lib.moe_apply(p, x, cfg)
        p_no = dict(p)
        p_no.pop("shared")
        y_without, _ = moe_lib.moe_apply(p_no, x, cfg)
        assert float(jnp.max(jnp.abs(y_with - y_without))) > 1e-6

    def test_vmappable(self, rng):
        """The train step vmaps MoE over the worker axis."""
        cfg = _cfg()
        p = moe_lib.moe_init(jax.random.PRNGKey(4), cfg)
        xs = jnp.asarray(rng.normal(size=(3, 1, 8, 32)), jnp.float32)
        ys, _ = jax.vmap(lambda x: moe_lib.moe_apply(p, x, cfg))(xs)
        assert ys.shape == xs.shape

    def test_load_balance_loss_ordering(self, rng):
        """Uniform routing scores a lower aux loss than collapsed routing."""
        cfg = _cfg(E=4, k=1)
        p = moe_lib.moe_init(jax.random.PRNGKey(5), cfg)
        x_div = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
        x_same = jnp.ones((1, 64, 32), jnp.float32)
        _, l_div = moe_lib.moe_apply(p, x_div, cfg)
        _, l_same = moe_lib.moe_apply(p, x_same, cfg)
        assert float(l_div["moe_aux"]) < float(l_same["moe_aux"])
