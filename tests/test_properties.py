"""Hypothesis property tests on system-level invariants of FA."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # Hermetic environments: seeded fallback generator (no shrinking) so the
    # property suite still runs; CI installs real hypothesis
    # (requirements-test.txt).
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (FlagConfig, fa_weights_from_gram, flag_aggregate,
                        flag_aggregate_gram)
from repro.core.gram import gram_matrix
from repro.dist.aggregation import AggregatorConfig, aggregate_tree

CASE = st.tuples(st.integers(5, 12), st.integers(16, 80),
                 st.integers(0, 99999))


def _mat(p, n, seed):
    r = np.random.default_rng(seed)
    mu = r.normal(size=n)
    return jnp.asarray((mu[None] + 0.5 * r.normal(size=(p, n)))
                       .astype(np.float32))


class TestFAInvariants:
    @given(CASE)
    @settings(max_examples=15, deadline=None)
    def test_rotation_equivariance(self, case):
        """FA commutes with orthogonal rotations of gradient space:
        FA(Q G) == Q FA(G).  (The subspace estimate is basis-free; the Gram
        — and hence the combine weights — are rotation invariant.)"""
        p, n, seed = case
        Gw = _mat(p, n, seed)
        r = np.random.default_rng(seed + 1)
        Q = jnp.asarray(np.linalg.qr(r.normal(size=(n, n)))[0]
                        .astype(np.float32))
        cfg = FlagConfig(lam=2.0)
        d1, _ = flag_aggregate_gram(Gw.T, cfg)
        d2, _ = flag_aggregate_gram(Q @ Gw.T, cfg)
        np.testing.assert_allclose(np.asarray(Q @ d1), np.asarray(d2),
                                   rtol=5e-2, atol=5e-3)

    @given(CASE)
    @settings(max_examples=15, deadline=None)
    def test_weights_rotation_invariant(self, case):
        p, n, seed = case
        Gw = _mat(p, n, seed)
        r = np.random.default_rng(seed + 1)
        Q = jnp.asarray(np.linalg.qr(r.normal(size=(n, n)))[0]
                        .astype(np.float32))
        cfg = FlagConfig(lam=2.0)
        c1, _ = fa_weights_from_gram(gram_matrix(Gw.T), cfg)
        c2, _ = fa_weights_from_gram(gram_matrix(Q @ Gw.T), cfg)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                                   rtol=2e-2, atol=2e-3)

    @given(CASE)
    @settings(max_examples=15, deadline=None)
    def test_explained_variance_bounds(self, case):
        p, n, seed = case
        Gw = _mat(p, n, seed)
        _, aux = fa_weights_from_gram(gram_matrix(Gw.T), FlagConfig(lam=1.0))
        v = np.asarray(aux["explained_variance"])
        assert (v >= -1e-5).all() and (v <= 1 + 1e-5).all()

    @given(CASE)
    @settings(max_examples=10, deadline=None)
    def test_gram_psd_and_symmetric(self, case):
        p, n, seed = case
        Gw = _mat(p, n, seed)
        K = np.asarray(gram_matrix(Gw.T))
        np.testing.assert_allclose(K, K.T, rtol=1e-5)
        assert np.linalg.eigvalsh(K).min() > -1e-2

    @given(st.tuples(st.integers(5, 10), st.integers(1, 4),
                     st.integers(0, 99999)))
    @settings(max_examples=10, deadline=None)
    def test_tree_aggregation_matches_flat_reference(self, case):
        """The tree-algebra invariant, generatively: over randomized pytree
        shapes and worker counts, ``aggregate_tree`` on a worker-major
        pytree == dense ``flag_aggregate`` on the concatenated (n, W)
        matrix (Gram additivity + combine linearity + Gram-vs-dense IRLS
        equivalence, composed)."""
        W, n_leaves, seed = case
        r = np.random.default_rng(seed)
        mu_scale = 1.0 + 0.5 * r.random()
        leaves = []
        for _ in range(n_leaves):
            shape = tuple(int(r.integers(2, 9))
                          for _ in range(int(r.integers(1, 4))))
            mu = r.normal(size=shape) * mu_scale
            leaves.append(jnp.asarray(
                (mu[None] + 0.5 * r.normal(size=(W,) + shape))
                .astype(np.float32)))
        tree = {f"leaf{i}": x for i, x in enumerate(leaves)}
        flat = jnp.concatenate([x.reshape(W, -1)
                                for x in jax.tree.leaves(tree)], axis=1)

        cfg = FlagConfig(lam=2.0)
        d_tree, aux = aggregate_tree(tree, AggregatorConfig(name="flag",
                                                            flag=cfg))
        got = np.concatenate([np.asarray(x).reshape(-1)
                              for x in jax.tree.leaves(d_tree)])
        want, _ = flag_aggregate(flat.T, cfg)
        scale = np.linalg.norm(np.asarray(want)) + 1e-6
        np.testing.assert_allclose(got / scale, np.asarray(want) / scale,
                                   rtol=5e-3, atol=5e-4)
        assert aux["weights"].shape == (W,)

    @given(CASE)
    @settings(max_examples=10, deadline=None)
    def test_aggregate_within_gradient_span(self, case):
        """d = G c lies in the column span of G (exact by construction in
        the Gram form — the paper's Y Y^T G 1 need not be, but the
        aggregation identity puts it there)."""
        p, n, seed = case
        Gw = _mat(p, n, seed)
        d, aux = flag_aggregate_gram(Gw.T, FlagConfig(lam=1.0))
        # least-squares residual of d against span(G^T)
        coef, *_ = np.linalg.lstsq(np.asarray(Gw.T), np.asarray(d),
                                   rcond=None)
        recon = np.asarray(Gw.T) @ coef
        rel = np.linalg.norm(recon - np.asarray(d)) / (
            np.linalg.norm(d) + 1e-30)
        assert rel < 1e-3
