"""Serve-layer tests: prefill/decode agreement through the serving API,
decode_loop golden tokens, and input validation.

``tests/test_models.py`` asserts decode==prefill at the *model* layer
(``transformer.decode_step``); this module covers the serving layer that
sits on top (``repro.dist.serve_step``): the jit-able serve step, the
lockstep decode loop, and the prompt handling around them — which had no
dedicated test module before.

One representative arch per block family: pure attention (smollm),
rgLRU+sliding-window attention (recurrentgemma), mLSTM/sLSTM (xlstm),
windowed MoE attention (mixtral).

Local rngs throughout (the shared session rng makes tolerances
order-dependent).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.dist.serve_step import (build_prefill_step, build_serve_step,
                                   decode_loop)
from repro.models import transformer

# one arch per block family (attn / rglru / xlstm / moe+window)
FAMILY_ARCHS = ["smollm-360m", "recurrentgemma-9b", "xlstm-1.3b",
                "mixtral-8x7b"]


def _setup(arch, seed=0, B=2, S=7):
    cfg = reduce_for_smoke(get_config(arch)).replace(frontend=None,
                                                     num_prefix_embeds=0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    # seed sequence, not hash(): str hashing is PYTHONHASHSEED-salted and
    # would make the prompts (and any tolerance failure) unreproducible
    rng = np.random.default_rng([seed, *arch.encode()])
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return cfg, params, prompts


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
class TestPrefillDecodeAgreement:
    def test_last_position_logits_match(self, arch):
        """Consuming the prompt through the serve-step cache layout (the
        exact layout decode_loop builds: max_len > S, fp32 caches) must
        reproduce build_prefill_step's last-position logits."""
        cfg, params, prompts = _setup(arch)
        B, S = prompts.shape
        max_len = S + 5
        prefill = build_prefill_step(cfg)
        logits_par = prefill(params, {"tokens": prompts})

        caches = transformer.init_caches(cfg, B, max_len, jnp.float32)
        lg = None
        for t in range(S):
            lg, caches = transformer.decode_step(
                params, prompts[:, t:t + 1], caches,
                jnp.asarray(t, jnp.int32), cfg, max_len=max_len)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_par[:, -1]),
                                   rtol=2e-2, atol=2e-2, err_msg=arch)

    def test_first_generated_token_is_prefill_argmax(self, arch):
        """decode_loop's first token == greedy argmax of the prefill
        logits at the last prompt position (the seeding contract)."""
        cfg, params, prompts = _setup(arch, seed=1)
        out = decode_loop(params, cfg, prompts, num_steps=1,
                          max_len=prompts.shape[1] + 2)
        logits = build_prefill_step(cfg)(params, {"tokens": prompts})
        want = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                      np.asarray(want), err_msg=arch)


@pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-1.3b"])
def test_decode_loop_golden_token_chain(arch):
    """Golden-token test: the whole greedy generation must equal the
    chain produced by repeatedly re-prefilling the growing sequence and
    taking the last-position argmax — an independent (cache-free)
    implementation of greedy decoding."""
    cfg, params, prompts = _setup(arch, seed=2, B=2, S=4)
    num_steps = 4
    out = decode_loop(params, cfg, prompts, num_steps=num_steps,
                      max_len=prompts.shape[1] + num_steps + 1)
    assert out.shape == (2, num_steps) and out.dtype == jnp.int32

    prefill = build_prefill_step(cfg)
    seq = prompts
    golden = []
    for _ in range(num_steps):
        logits = prefill(params, {"tokens": seq})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        golden.append(nxt)
        seq = jnp.concatenate([seq, nxt], axis=1)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.concatenate(golden, axis=1)),
                                  err_msg=arch)


class TestServeStep:
    def test_shapes_and_dtype(self):
        cfg, params, prompts = _setup("smollm-360m", seed=3)
        B = prompts.shape[0]
        step = jax.jit(build_serve_step(cfg, max_len=8))
        caches = transformer.init_caches(cfg, B, 8, jnp.float32)
        nxt, caches = step(params, caches, prompts[:, :1],
                           jnp.zeros((), jnp.int32))
        assert nxt.shape == (B, 1) and nxt.dtype == jnp.int32
        assert 0 <= int(jnp.min(nxt)) and int(jnp.max(nxt)) < cfg.vocab_size


class TestDecodeLoopValidation:
    def test_empty_prompt_rejected(self):
        cfg, params, _ = _setup("smollm-360m", seed=4)
        empty = jnp.zeros((2, 0), jnp.int32)
        with pytest.raises(ValueError, match="non-empty prompt"):
            decode_loop(params, cfg, empty, num_steps=3, max_len=8)

    def test_zero_generation_rejected(self):
        cfg, params, prompts = _setup("smollm-360m", seed=7)
        with pytest.raises(ValueError, match="num_steps >= 1"):
            decode_loop(params, cfg, prompts, num_steps=0, max_len=16)

    def test_overlong_generation_rejected(self):
        cfg, params, prompts = _setup("smollm-360m", seed=5)
        with pytest.raises(ValueError, match="exceeds max_len"):
            decode_loop(params, cfg, prompts, num_steps=8,
                        max_len=prompts.shape[1] + 2)

    def test_single_token_prompt_works(self):
        """S=1 is the minimal legal prompt (the BOS-seeding pattern the
        S==0 error message recommends)."""
        cfg, params, prompts = _setup("smollm-360m", seed=6)
        out = decode_loop(params, cfg, prompts[:, :1], num_steps=2,
                          max_len=4)
        assert out.shape == (2, 2)
