"""Config registry + input-spec tests (deliverable f plumbing)."""

from __future__ import annotations

import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduce_for_smoke
from repro.configs.shapes import SHAPES, get_shape, input_specs

ASSIGNED = {
    # arch id -> (layers, d_model, heads, kv, d_ff, vocab)
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "smollm-360m": (32, 960, 15, 5, 2560, 49152),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
    "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
}


class TestRegistry:
    def test_all_ten_assigned(self):
        assert set(ARCHS) == set(ASSIGNED)

    @pytest.mark.parametrize("name", sorted(ASSIGNED))
    def test_exact_assigned_numbers(self, name):
        L, d, h, kv, ff, v = ASSIGNED[name]
        c = get_config(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v)

    def test_moe_settings(self):
        mx = get_config("mixtral-8x7b").moe
        assert (mx.num_experts, mx.top_k) == (8, 2)
        ds = get_config("deepseek-moe-16b").moe
        assert (ds.num_experts, ds.top_k, ds.num_shared) == (64, 6, 2)

    def test_tp_dims_divisible_by_model_axis(self):
        """Every Megatron-TP dim divides the 16-way model axis."""
        for c in ARCHS.values():
            assert c.d_model % 16 == 0 or c.d_model == 960  # smollm: qkv dim
            assert (c.num_heads * c.head_dim) % 16 == 0
            assert c.vocab_size % 16 == 0
            if c.d_ff:
                assert c.d_ff % 16 == 0

    @pytest.mark.parametrize("name", sorted(ASSIGNED))
    def test_smoke_reduction_bounds(self, name):
        c = reduce_for_smoke(get_config(name))
        assert c.num_layers <= 3
        assert c.d_model <= 512
        if c.moe:
            assert c.moe.num_experts <= 4


class TestShapes:
    def test_four_assigned_shapes(self):
        want = {"train_4k": (4096, 256, "train"),
                "prefill_32k": (32768, 32, "prefill"),
                "decode_32k": (32768, 128, "decode"),
                "long_500k": (524288, 1, "decode")}
        assert set(SHAPES) == set(want)
        for k, (s, b, kind) in want.items():
            sh = get_shape(k)
            assert (sh.seq_len, sh.global_batch, sh.kind) == (s, b, kind)

    def test_train_specs_have_worker_axis(self):
        cfg = get_config("smollm-360m")
        sp = input_specs(cfg, get_shape("train_4k"), workers=16)
        assert sp["tokens"].shape == (16, 16, 4096)
        assert sp["labels"].dtype == jnp.int32

    def test_vlm_specs_include_patch_embeddings(self):
        cfg = get_config("phi-3-vision-4.2b")
        sp = input_specs(cfg, get_shape("prefill_32k"))
        assert sp["prefix_embeds"].shape == (32, 256, 1024)
        # token length shrinks by the patch prefix so total seq is 32768
        assert sp["tokens"].shape == (32, 32768 - 256)

    def test_decode_specs(self):
        cfg = get_config("mixtral-8x7b")
        sp = input_specs(cfg, get_shape("decode_32k"))
        assert sp["tokens"].shape == (128, 1)
        assert sp["step"].shape == ()

    def test_audio_specs(self):
        cfg = get_config("musicgen-medium")
        sp = input_specs(cfg, get_shape("train_4k"), workers=32)
        assert sp["prefix_embeds"].shape == (32, 8, 64, 768)
