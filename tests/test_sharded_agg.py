"""Mesh-sharded aggregation: sharded == single-device for all 11 rules.

The real assertions need a multi-device backend, so this module has two
modes:

* under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
  ``shard-smoke`` lane) every test below runs directly on an 8-device
  host mesh;
* in a plain single-device session (the tier-1 suite) the one
  non-skipped test re-runs this module in a subprocess with the flag
  set, so the sharded path is exercised by the tier-1 gate too —
  the pattern ``tests/conftest.py`` prescribes for device-hungry tests.

Coverage: weight + update equivalence for all 11 aggregators (ragged /
padded leaf widths), bit-identical combines for the linear-combination
family given a shared Gram, mask= and gram= composition, the
``compressed_aggregate`` bridge, the train step with
``TrainConfig.sharded_agg``, and the acceptance HLO check that no
per-device tensor carries the full unsharded coordinate dimension.

Local rngs throughout (the shared session rng makes tolerances
order-dependent).
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import Graph, check_shape
from repro.comm import CommConfig, init_ef
from repro.configs import get_config, reduce_for_smoke
from repro.core import FlagConfig
from repro.dist.aggregation import (GRAM_RULES, AggregatorConfig,
                                    aggregate_tree, compressed_aggregate,
                                    tree_gram)
from repro.dist.sharded import (coord_axes, n_coord_shards,
                                sharded_tree_combine, sharded_tree_gram)
from repro.dist.sharding import use_sharding
from repro.dist.train_step import (TrainConfig, build_train_step,
                                   init_train_state)
from repro.launch.mesh import make_host_mesh
from repro.optim import constant, sgd

NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_"
                     "count=8 (tier-1 runs this module via the "
                     "subprocess launcher test instead)")

ALL_RULES = ["mean", "flag", "pca", "median", "trimmed_mean", "meamed",
             "phocas", "krum", "multi_krum", "bulyan", "geomed"]

ACTIVE = np.array([1, 0, 1, 1, 0, 1, 1, 0, 1], bool)


def _cfg(name):
    # explicit m + tol=0 -> both runs execute the same IRLS iteration
    # count, so comparisons are numerics-only (same convention as
    # tests/test_membership.py)
    return AggregatorConfig(name=name, f=2,
                            flag=FlagConfig(lam=2.0, m=3, tol=0.0))


def _tree(seed, W=9):
    """Ragged leaf widths on purpose: 4096 divides an 8-shard mesh
    cleanly, 130 and 33*3 exercise the zero-padding path."""
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(W, 4096)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(W, 130)), jnp.float32),
                  "d": jnp.asarray(rng.normal(size=(W, 33, 3)),
                                   jnp.float32)}}
    return jax.tree.map(
        lambda l: l * jnp.linspace(0.5, 2.0, W).reshape(
            (W,) + (1,) * (l.ndim - 1)), tree)


def test_runs_on_forced_host_mesh_in_subprocess():
    """Tier-1 entry point: on a single-device backend, re-run this module
    with 8 forced host CPU devices so the sharded assertions execute."""
    if NDEV >= 8:
        pytest.skip("already on a multi-device backend")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    repo = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        env=env, cwd=repo, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"sharded suite failed on the forced " \
                              f"8-device mesh:\n{r.stdout}\n{r.stderr}"


@needs_mesh
class TestShardedGram:
    def test_psum_matches_flat(self):
        tree = _tree(1)
        mesh = make_host_mesh(8)
        K = sharded_tree_gram(tree, mesh)
        flat = jnp.concatenate([x.reshape(9, -1)
                                for x in jax.tree.leaves(tree)], axis=1)
        np.testing.assert_allclose(np.asarray(K), np.asarray(flat @ flat.T),
                                   rtol=1e-5, atol=1e-3)

    def test_matches_single_device_gram(self):
        tree = _tree(2)
        mesh = make_host_mesh(8)
        K_s = sharded_tree_gram(tree, mesh)
        K_1 = tree_gram(tree)
        np.testing.assert_allclose(np.asarray(K_s), np.asarray(K_1),
                                   rtol=1e-6, atol=5e-4)

    @pytest.mark.parametrize("n_devices", [1, 2, 4, 8])
    def test_every_submesh_size(self, n_devices):
        """The benchmark sweep's device counts all agree with each other."""
        tree = _tree(3)
        mesh = make_host_mesh(n_devices)
        assert n_coord_shards(mesh) == n_devices
        K = sharded_tree_gram(tree, mesh)
        np.testing.assert_allclose(np.asarray(K), np.asarray(tree_gram(tree)),
                                   rtol=1e-6, atol=5e-4)

    def test_sketch_stride_diag_unbiased(self):
        rng = np.random.default_rng(5)
        tree = {"x": jnp.asarray(rng.normal(size=(5, 37_000)), jnp.float32)}
        mesh = make_host_mesh(8)
        K = sharded_tree_gram(tree, mesh)
        Ks = sharded_tree_gram(tree, mesh, sketch_stride=4)
        ratio = np.asarray(jnp.diag(Ks) / jnp.diag(K))
        assert (ratio > 0.8).all() and (ratio < 1.25).all()


@needs_mesh
@pytest.mark.parametrize("name", ALL_RULES)
class TestShardedEqualsSingle:
    def test_equivalence(self, name):
        tree = _tree(7)
        mesh = make_host_mesh(8)
        d_s, aux_s = aggregate_tree(tree, _cfg(name), sharded=mesh)
        d_1, aux_1 = aggregate_tree(tree, _cfg(name))
        np.testing.assert_allclose(np.asarray(aux_s["weights"]),
                                   np.asarray(aux_1["weights"]),
                                   rtol=2e-4, atol=2e-5)
        for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
            assert a.shape == b.shape
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)

    def test_masked_equivalence(self, name):
        tree = _tree(8)
        mesh = make_host_mesh(8)
        mask = jnp.asarray(ACTIVE, jnp.float32)
        d_s, aux_s = aggregate_tree(tree, _cfg(name), mask=mask,
                                    sharded=mesh)
        d_1, aux_1 = aggregate_tree(tree, _cfg(name), mask=mask)
        w = np.asarray(aux_s["weights"])
        assert np.all(w[~ACTIVE] == 0.0)
        np.testing.assert_allclose(w, np.asarray(aux_1["weights"]),
                                   rtol=2e-4, atol=2e-5)
        for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


@needs_mesh
@pytest.mark.parametrize("name", sorted(GRAM_RULES))
def test_combine_bit_identical_given_same_gram(name):
    """Acceptance: the FA/mean linear-combination family produces a
    BIT-identical combined update — the per-coordinate worker reduction
    is unchanged by the sharding, so with the Gram stage pinned (gram=,
    composing exactly as the sketch codecs use it) every downstream bit
    matches."""
    tree = _tree(11)
    K = tree_gram(tree)
    mesh = make_host_mesh(8)
    d_s, aux_s = aggregate_tree(tree, _cfg(name), gram=K, sharded=mesh)
    d_1, aux_1 = aggregate_tree(tree, _cfg(name), gram=K)
    np.testing.assert_array_equal(np.asarray(aux_s["weights"]),
                                  np.asarray(aux_1["weights"]))
    for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_mesh
def test_mean_bit_identical_without_gram_override():
    """mean's weights don't depend on K at all, so the whole sharded
    aggregate is bit-identical out of the box."""
    tree = _tree(12)
    mesh = make_host_mesh(8)
    d_s, _ = aggregate_tree(tree, _cfg("mean"), sharded=mesh)
    d_1, _ = aggregate_tree(tree, _cfg("mean"))
    for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_mesh
def test_coordwise_rules_bit_identical():
    """Coordinate rules see exactly the same per-coordinate worker column
    on every shard — not just close, identical."""
    tree = _tree(13)
    mesh = make_host_mesh(8)
    for name in ("median", "trimmed_mean", "meamed", "phocas"):
        d_s, _ = aggregate_tree(tree, _cfg(name), sharded=mesh)
        d_1, _ = aggregate_tree(tree, _cfg(name))
        for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_mesh
@pytest.mark.parametrize("name", ["median", "trimmed_mean", "meamed",
                                  "phocas", "bulyan"])
def test_coordwise_rules_pallas_impl_bit_identical(name):
    """The selection-network dispatch composes with the shard-local path:
    at ``impl='pallas'`` (the production dispatch — the fused network
    lowering on a CPU host) the sharded coordinate rules and Bulyan's
    coordinate stage stay BIT-identical to the single-device run, across
    ragged/padded leaves, with and without ``mask=``."""
    tree = _tree(14)
    mesh = make_host_mesh(8)
    cfg = AggregatorConfig(name=name, f=2, impl="pallas",
                           flag=FlagConfig(lam=2.0, m=3, tol=0.0))
    d_s, _ = aggregate_tree(tree, cfg, sharded=mesh)
    d_1, _ = aggregate_tree(tree, cfg)
    for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mask = jnp.asarray(ACTIVE, jnp.float32)
    d_sm, _ = aggregate_tree(tree, cfg, mask=mask, sharded=mesh)
    d_1m, _ = aggregate_tree(tree, cfg, mask=mask)
    for a, b in zip(jax.tree.leaves(d_sm), jax.tree.leaves(d_1m)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_mesh
class TestNoFullCoordinateDim:
    """Acceptance: post-SPMD-partition HLO shapes are per-device — none
    may carry the full unsharded coordinate dimension."""

    W = 6
    SHAPES = {"a": (8192,), "b": (2048, 2)}          # flat: 8192, 4096

    def _compiled_text(self, name):
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = make_host_mesh(8)
        axes = coord_axes(mesh)
        cfg = AggregatorConfig(name=name, flag=FlagConfig(lam=2.0, m=3))
        args = {
            k: jax.ShapeDtypeStruct(
                (self.W,) + s, jnp.float32,
                sharding=NamedSharding(
                    mesh, P(None, axes, *([None] * (len(s) - 1)))))
            for k, s in self.SHAPES.items()}
        fn = jax.jit(lambda t: aggregate_tree(t, cfg, sharded=mesh))
        return fn.lower(args).compile().as_text()

    @pytest.mark.parametrize("name", ["flag", "mean", "median", "bulyan"])
    def test_no_device_tensor_holds_full_width(self, name):
        # mechanism = the SHAPE rule (forbidden + required-dims sanity);
        # this test only declares the dims, tools/jaxlint.py sweeps the
        # same invariant over all 11 rules.
        findings = check_shape(
            Graph(f"sharded/{name}", None, self._compiled_text(name)),
            forbidden_dims={8192, 4096, 2048, 8192 + 4096},
            require_dims={8192 // 8, 4096 // 8})
        assert not findings, "\n".join(f.render() for f in findings)

    def test_single_device_path_does_hold_full_width(self):
        """Detector sanity: without sharded=, the full width appears."""
        cfg = AggregatorConfig(name="flag", flag=FlagConfig(lam=2.0, m=3))
        args = {k: jax.ShapeDtypeStruct((self.W,) + s, jnp.float32)
                for k, s in self.SHAPES.items()}
        txt = jax.jit(lambda t: aggregate_tree(t, cfg)).lower(
            args).compile().as_text()
        findings = check_shape(Graph("unsharded/flag", None, txt),
                               forbidden_dims={8192})
        assert findings, "SHAPE rule missed the full width on one device"


@needs_mesh
class TestComposition:
    def test_sharded_true_uses_context_mesh(self):
        tree = _tree(17)
        mesh = make_host_mesh(8)
        with use_sharding(mesh, {}):
            d_s, aux_s = aggregate_tree(tree, _cfg("flag"), sharded=True)
        d_1, aux_1 = aggregate_tree(tree, _cfg("flag"))
        np.testing.assert_allclose(np.asarray(aux_s["weights"]),
                                   np.asarray(aux_1["weights"]),
                                   rtol=2e-4, atol=2e-5)

    def test_sharded_true_without_mesh_raises(self):
        with pytest.raises(ValueError, match="needs an active mesh"):
            aggregate_tree(_tree(18), _cfg("flag"), sharded=True)

    def test_sharded_combine_matches_tree_combine(self):
        from repro.dist.aggregation import tree_combine
        tree = _tree(19)
        mesh = make_host_mesh(8)
        c = jnp.asarray(np.random.default_rng(19).normal(size=(9,)),
                        jnp.float32)
        d_s = sharded_tree_combine(tree, c, mesh)
        d_1 = tree_combine(tree, c)
        for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_compressed_sketch_gram_feed(self):
        """CountSketch weights from the (unsharded, tiny) payload Gram +
        shard-local exact combine == the single-device bridge."""
        tree = _tree(20)
        mesh = make_host_mesh(8)
        comm = CommConfig(codec="countsketch", sketch_ratio=1.0 / 8.0)
        d_s, aux_s, _ = compressed_aggregate(tree, _cfg("flag"), comm,
                                             sharded=mesh)
        d_1, aux_1, _ = compressed_aggregate(tree, _cfg("flag"), comm)
        np.testing.assert_allclose(np.asarray(aux_s["weights"]),
                                   np.asarray(aux_1["weights"]),
                                   rtol=2e-4, atol=2e-5)
        assert float(aux_s["comm_bits"]) == float(aux_1["comm_bits"])
        for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)

    def test_compressed_ef_codec(self):
        tree = _tree(21)
        mesh = make_host_mesh(8)
        params = jax.tree.map(lambda l: l[0], tree)
        comm = CommConfig(codec="signsgd")
        ef0 = init_ef(params, 9)
        d_s, _, ef_s = compressed_aggregate(tree, _cfg("mean"), comm, ef0,
                                            sharded=mesh)
        d_1, _, ef_1 = compressed_aggregate(tree, _cfg("mean"), comm, ef0)
        for a, b in zip(jax.tree.leaves(d_s), jax.tree.leaves(d_1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(ef_s), jax.tree.leaves(ef_1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


@needs_mesh
def test_train_step_sharded_matches_single():
    """TrainConfig.sharded_agg under an active mesh: same trajectory as
    the single-device step (the gradient stack goes straight from the
    vmapped backward into coordinate shards — no device-0 hop, asserted
    separately by the HLO test above)."""
    cfg = reduce_for_smoke(get_config("smollm-360m")).replace(
        frontend=None, num_prefix_embeds=0)
    W = 4
    opt = sgd(momentum=0.9)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    rng = np.random.default_rng(23)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (W, 2, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (W, 2, 16)),
                              jnp.int32),
    }
    agg = AggregatorConfig(name="flag", flag=FlagConfig(lam=0.0,
                                                        regularizer="none",
                                                        tol=0.0))
    outs = {}
    mesh = make_host_mesh(8)
    for sharded in (False, True):
        tc = TrainConfig(aggregator=agg, sharded_agg=sharded)
        step = jax.jit(build_train_step(cfg, tc, opt, constant(1e-3)))
        if sharded:
            with use_sharding(mesh, {}):
                outs[sharded] = step(params, opt_state, batch,
                                     jax.random.PRNGKey(1),
                                     jnp.zeros((), jnp.int32))
        else:
            outs[sharded] = step(params, opt_state, batch,
                                 jax.random.PRNGKey(1),
                                 jnp.zeros((), jnp.int32))
    p_s, _, m_s = outs[True]
    p_1, _, m_1 = outs[False]
    assert bool(jnp.isfinite(m_s["loss"]))
    np.testing.assert_allclose(float(m_s["loss"]), float(m_1["loss"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m_s["fa_weights"]),
                               np.asarray(m_1["fa_weights"]),
                               rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p_s), jax.tree.leaves(p_1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)
