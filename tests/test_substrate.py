"""Substrate tests: data, augmentations, optimizers, schedules, checkpoint."""

from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import augment
from repro.data.pipeline import WorkerDataConfig, lm_worker_batches
from repro.data.synthetic import SyntheticImages, SyntheticLM
from repro.optim import adamw, cosine, sgd, step_decay, warmup_cosine


class TestSyntheticImages:
    def test_deterministic(self):
        a = SyntheticImages(seed=3).sample(jax.random.PRNGKey(0), 8)
        b = SyntheticImages(seed=3).sample(jax.random.PRNGKey(0), 8)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))

    def test_range_and_shapes(self):
        x, y = SyntheticImages().sample(jax.random.PRNGKey(1), 16)
        assert x.shape == (16, 32, 32, 3)
        assert float(x.min()) >= 0.0 and float(x.max()) <= 1.0
        assert int(y.min()) >= 0 and int(y.max()) < 10

    def test_learnable(self):
        """Templates are separable: nearest-template classification works."""
        task = SyntheticImages(noise=0.15)
        x, y = task.sample(jax.random.PRNGKey(2), 256)
        t = task.templates.reshape(10, -1)
        d = jnp.linalg.norm(x.reshape(256, -1)[:, None] - t[None], axis=-1)
        acc = float(jnp.mean(jnp.argmin(d, -1) == y))
        assert acc > 0.9


class TestSyntheticLM:
    def test_deterministic_and_learnable_structure(self):
        task = SyntheticLM(vocab_size=128, seed=1)
        b1 = task.batch(jax.random.PRNGKey(0), 4, 32)
        b2 = task.batch(jax.random.PRNGKey(0), 4, 32)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        # labels are one of <=branch successors of the current token
        succ = np.asarray(task._succ(b1["tokens"]))
        lab = np.asarray(b1["labels"])[..., None]
        assert bool(np.all(np.any(succ == lab, axis=-1)))

    def test_worker_batches_shapes(self):
        task = SyntheticLM(vocab_size=64)
        wdc = WorkerDataConfig(workers=3, per_worker_batch=2)
        b = lm_worker_batches(task, wdc, step=0, seq_len=16)
        assert b["tokens"].shape == (3, 2, 16)
        # workers see different data
        assert not np.array_equal(np.asarray(b["tokens"][0]),
                                  np.asarray(b["tokens"][1]))


class TestAugment:
    @pytest.fixture
    def imgs(self, rng):
        return jnp.asarray(rng.uniform(0, 1, size=(4, 32, 32, 3)),
                           jnp.float32)

    def test_lotka_volterra_range_and_nonlinearity(self, imgs):
        out = augment.lotka_volterra(imgs)
        assert out.shape == imgs.shape
        assert float(out.min()) >= 0 and float(out.max()) <= 1
        # nonlinear: not an affine map of the input
        out2 = augment.lotka_volterra(0.5 * imgs)
        assert float(jnp.max(jnp.abs(out2 - 0.5 * out))) > 1e-3

    def test_cat_map_is_permutation(self, imgs):
        out = augment.cat_map(imgs)
        np.testing.assert_allclose(np.sort(np.asarray(out).ravel()),
                                   np.sort(np.asarray(imgs).ravel()),
                                   rtol=1e-6)

    def test_cat_map_periodicity(self, imgs):
        """Arnold's cat map on a 32x32 grid has a small period (<=24)."""
        out = imgs
        for _ in range(24):
            out = augment.cat_map(out)
        np.testing.assert_allclose(np.asarray(out), np.asarray(imgs),
                                   rtol=1e-6)

    def test_smooth_cat_map_runs(self, imgs):
        out = augment.smooth_cat_map(imgs)
        assert out.shape == imgs.shape
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_rk4_accuracy_exponential(self):
        """RK4 on dx/dt = -x matches exp to ~1e-6 at dt=1/16."""
        field = lambda s: (-s[0], -s[1])
        x0 = (jnp.ones(()), jnp.full((), 2.0))
        out = augment.rk4(field, x0, 1.0 / 16, 16)
        np.testing.assert_allclose(float(out[0]), np.exp(-1.0), rtol=1e-6)


class TestOptim:
    def _quad(self, params):
        return sum(jnp.sum(p ** 2) for p in jax.tree.leaves(params))

    @pytest.mark.parametrize("make", [lambda: sgd(momentum=0.9),
                                      lambda: adamw(weight_decay=0.0)])
    def test_converges_on_quadratic(self, make):
        opt = make()
        params = {"a": jnp.ones((4,)), "b": jnp.full((2, 2), -2.0)}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(self._quad)(params)
            upd, state = opt.update(g, state, params, 0.05)
            params = jax.tree.map(lambda p, u: p + u, params, upd)
        assert float(self._quad(params)) < 1e-3

    def test_schedules(self):
        s = step_decay(1.0, decay=0.2, every=10)
        assert float(s(jnp.asarray(0))) == 1.0
        np.testing.assert_allclose(float(s(jnp.asarray(10))), 0.2)
        c = cosine(1.0, 100)
        assert float(c(jnp.asarray(0))) == 1.0
        assert float(c(jnp.asarray(100))) == pytest.approx(0.1)
        w = warmup_cosine(1.0, 100, warmup=10)
        assert float(w(jnp.asarray(0))) == 0.0
        assert float(w(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)


class TestCheckpoint:
    def test_roundtrip(self, rng):
        tree = {"p": {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
                      "b": jnp.asarray(rng.normal(size=(3,)), jnp.bfloat16)},
                "step": jnp.asarray(7, jnp.int32)}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 42, tree)
            restored, step = load_checkpoint(d, tree)
            assert step == 42
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a, np.float32),
                                              np.asarray(b, np.float32))

    def test_latest_step(self, rng):
        tree = {"x": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as d:
            assert latest_step(d) is None
            save_checkpoint(d, 1, tree)
            save_checkpoint(d, 5, tree)
            assert latest_step(d) == 5
            _, step = load_checkpoint(d, tree)
            assert step == 5

    def test_shape_mismatch_raises(self, rng):
        tree = {"x": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, tree)
            with pytest.raises(ValueError):
                load_checkpoint(d, {"x": jnp.zeros((3,))})
