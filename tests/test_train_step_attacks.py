"""Attack-matrix train-step tests: every threat model in
``repro.core.attacks`` x {flag, krum, mean}, asserting the step stays
finite and honest workers dominate the aggregated update.

Regime: all workers receive the *same* SyntheticLM batch (lockstep), so
honest gradients coincide and each attack is a pure displacement — the
concentration setting the paper's robustness analysis assumes (honest
gradients agree; Byzantine ones deviate).  Dominance is asserted on the
``worker_influence`` metric (each worker's normalized share of the
aggregated update's L2 mass, |c_i| * ||g_i||): raw combine weights c are
paper-faithful but misleading under degenerate norms (a zero-gradient
worker has huge c yet zero contribution).

Known, literature-documented exceptions are asserted as such rather than
papered over:

* krum x alie — ALIE [Baruch et al. 2019] stays inside the honest
  variance envelope; in the lockstep regime (zero honest variance) the
  Byzantine gradient *equals* the honest one, ties all Krum scores, and
  argmin picks worker 0.  The attack is a no-op, so only finiteness is
  meaningful.
* mean under large-norm attacks — mean is the non-robust baseline
  (paper Fig. 2); its uniform combine weights are asserted (metric
  plumbing), not influence dominance, which genuinely fails under e.g.
  sign_flip — that contrast is FA's selling point.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks
from repro.core.flag import FlagConfig
from repro.data.synthetic import SyntheticLM
from repro.dist.aggregation import AggregatorConfig
from repro.dist.train_step import (TrainConfig, build_train_step,
                                   init_train_state)
from repro.models.config import ModelConfig
from repro.optim import constant, sgd

W, B, S, F = 6, 4, 32, 2

CFG = ModelConfig(name="tiny-attack", arch_type="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128, compute_dtype="float32")

ATTACK_NAMES = sorted(a for a in attacks.ATTACKS if a != "none")


@pytest.fixture(scope="module")
def lockstep_batch():
    one = SyntheticLM(vocab_size=CFG.vocab_size).batch(
        jax.random.PRNGKey(7), B, S)
    return {k: jnp.broadcast_to(v[None], (W,) + v.shape)
            for k, v in one.items()}


@pytest.fixture(scope="module")
def train_state():
    return init_train_state(jax.random.PRNGKey(0), CFG, sgd(momentum=0.9))


def _run_step(train_state, batch, agg_name, attack):
    params, opt_state = train_state
    tc = TrainConfig(
        aggregator=AggregatorConfig(name=agg_name, f=F,
                                    flag=FlagConfig(lam=float(W))),
        attack=attack, attack_f=F)
    step = jax.jit(build_train_step(CFG, tc, sgd(momentum=0.9),
                                    constant(1e-3)))
    p1, _, m = step(params, opt_state, batch, jax.random.PRNGKey(100),
                    jnp.zeros((), jnp.int32))
    return p1, m


def _assert_finite_step(p1, m):
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_global_norm"]))
    assert m["fa_weights"].shape == (W,)
    assert bool(jnp.all(jnp.isfinite(m["worker_influence"])))
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in jax.tree.leaves(p1))


@pytest.mark.parametrize("attack", ATTACK_NAMES)
class TestFlagUnderAttack:
    def test_finite_and_honest_dominate(self, lockstep_batch, train_state,
                                        attack):
        p1, m = _run_step(train_state, lockstep_batch, "flag", attack)
        _assert_finite_step(p1, m)
        infl = np.asarray(m["worker_influence"])
        assert infl[F:].sum() > infl[:F].sum(), \
            f"honest influence {infl[F:].sum():.3f} <= byzantine " \
            f"{infl[:F].sum():.3f} under {attack}"


@pytest.mark.parametrize("attack", ATTACK_NAMES)
class TestKrumUnderAttack:
    def test_finite_and_selects_honest(self, lockstep_batch, train_state,
                                       attack):
        p1, m = _run_step(train_state, lockstep_batch, "krum", attack)
        _assert_finite_step(p1, m)
        if attack == "alie":
            # ALIE degenerates to a no-op in the lockstep regime (byz ==
            # honest gradient): selection ties are meaningless.  The real
            # krum-vs-ALIE failure is covered by the flag dominance above.
            return
        sel = int(np.argmax(np.abs(np.asarray(m["fa_weights"]))))
        assert sel >= F, f"krum selected Byzantine worker {sel} under {attack}"
        infl = np.asarray(m["worker_influence"])
        assert infl[F:].sum() > infl[:F].sum()


@pytest.mark.parametrize("attack", ATTACK_NAMES)
class TestMeanUnderAttack:
    def test_finite_and_uniform_weights(self, lockstep_batch, train_state,
                                        attack):
        p1, m = _run_step(train_state, lockstep_batch, "mean", attack)
        _assert_finite_step(p1, m)
        w = np.abs(np.asarray(m["fa_weights"]))
        np.testing.assert_allclose(w, np.full((W,), 1.0 / W), rtol=1e-6)
        assert w[F:].sum() > w[:F].sum()
