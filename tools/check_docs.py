"""Docs checks: intra-repo markdown links + doctests in fenced examples.

Two passes, both over the repo's markdown tree (root *.md + docs/):

1. **Link check** — every relative markdown link `[text](path)` must
   resolve to an existing file (anchors are stripped; http/https/mailto
   links are skipped).  Broken links are listed and fail the run.
2. **Doctests** — fenced ```python blocks in docs/*.md and README.md that
   contain `>>>` prompts run through `doctest` (needs `PYTHONPATH=src`).

Exit status is non-zero on any failure, so CI can gate on it:

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def md_files() -> list[Path]:
    return sorted(list(REPO.glob("*.md")) + list((REPO / "docs").glob("*.md")))


def check_links() -> list[str]:
    errors = []
    for md in md_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(SKIP_SCHEMES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def check_doctests() -> list[str]:
    errors = []
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS
                                   | doctest.NORMALIZE_WHITESPACE)
    parser = doctest.DocTestParser()
    docs = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    for md in docs:
        if not md.exists():
            continue
        for i, block in enumerate(FENCE_RE.findall(md.read_text())):
            if ">>>" not in block:
                continue
            name = f"{md.relative_to(REPO)}[block {i}]"
            test = parser.get_doctest(block, {}, name, str(md), 0)
            out: list[str] = []
            runner.run(test, out=out.append)
            if runner.failures:
                errors.append(f"{name}: doctest failed\n" + "".join(out))
                runner = doctest.DocTestRunner(
                    optionflags=doctest.ELLIPSIS
                    | doctest.NORMALIZE_WHITESPACE)
    return errors


def main() -> int:
    link_errors = check_links()
    doc_errors = check_doctests()
    for e in link_errors + doc_errors:
        print(f"FAIL: {e}", file=sys.stderr)
    n_md = len(md_files())
    if link_errors or doc_errors:
        print(f"{len(link_errors)} broken links, {len(doc_errors)} doctest "
              f"failures across {n_md} markdown files", file=sys.stderr)
        return 1
    print(f"docs OK: {n_md} markdown files, links + doctests clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
