#!/usr/bin/env python
"""jaxlint: sweep the repo's public entry points through repro.analysis.

Lints every entry point in :mod:`repro.analysis.entrypoints` — all 11
aggregation rules x {plain, masked, sketch} (x sharded with >= 8
devices), the gram solver, the compressed bridges, the bf16 serve path,
the train step, the recompile harness, and the Pallas kernel block
(every production ``pallas_call`` under the KTILING / KRACE / KVMEM /
KPRECISION / KSENTINEL families) — and exits nonzero on any finding.
This is the gating check of the CI ``lint-contracts`` lane.

Usage:
  PYTHONPATH=src python tools/jaxlint.py [options]

Options:
  --sharded {auto,force,skip}   mesh variants (default auto: run iff >= 8
                                devices; the script forces an 8-device
                                host platform when none is configured)
  --entry SUBSTR [SUBSTR ...]   lint only entries whose name contains any
                                (``--only`` is the legacy alias)
  --rule RULE [RULE ...]        keep only findings from these rule
                                families (e.g. ``--rule krace kvmem``);
                                entries still all run — the filter is on
                                what gates
  --json PATH                   also write the machine-readable findings
                                report to PATH (``-`` for stdout); the CI
                                lane uploads it as an artifact on failure
  --list                        print the entry-point names and exit
  -q / --quiet                  findings only, no per-entry progress
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# Sharded variants need devices; force a host platform before jax loads
# (mirrors the tests' subprocess pattern) unless the caller configured one.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sharded", choices=("auto", "force", "skip"),
                    default="auto")
    ap.add_argument("--entry", "--only", nargs="+", default=None,
                    metavar="SUBSTR", dest="entry")
    ap.add_argument("--rule", nargs="+", default=None, metavar="RULE")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.analysis.entrypoints import run_sweep, sweep_entries
    from repro.analysis.findings import Report
    from repro.analysis.rules import RULES

    if args.rule:
        unknown = sorted(set(args.rule) - set(RULES))
        if unknown:
            ap.error(f"unknown rule(s) {unknown}; known: {sorted(RULES)}")

    if args.list:
        for e in sweep_entries(sharded=args.sharded):
            print(e.name)
        return 0

    progress = None
    if not args.quiet:
        progress = lambda name: print(f"lint {name}", flush=True)
    report = run_sweep(sharded=args.sharded, names=args.entry,
                       progress=progress)
    if args.rule:
        filtered = Report()
        for name, fs in report.sections:
            filtered.add(name, [f for f in fs if f.rule in args.rule])
        report = filtered

    if args.json:
        payload = json.dumps(report.to_dict(), indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as fh:
                fh.write(payload + "\n")

    print()
    print(report.render())
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
